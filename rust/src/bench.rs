//! In-crate micro/meso-benchmark harness (criterion is not in the offline
//! registry).
//!
//! Mirrors criterion's core loop: warmup, adaptive iteration count targeting
//! a measurement budget, and robust statistics (mean, σ, p50, p99,
//! throughput). Benches under `benches/` are `harness = false` binaries that
//! call into this module and print aligned tables; `cargo bench` therefore
//! runs the full paper-figure regeneration suite.
//!
//! Machine-readable reports (`BENCH_*.json`) all build through ONE
//! [`BenchReport`] builder: every report carries the same envelope
//! (`unit`, `threads`) plus report-specific fields, and every writer
//! resolves its output path through the same `POGO_BENCH_JSON_*` redirect
//! convention — so the schema CI's `jq` gates parse and the redirect
//! behavior cannot drift between emitters.

use crate::util::Stopwatch;
use std::time::Duration;

/// One benchmark's collected statistics, in seconds.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub stddev: f64,
    pub p50: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<f64>,
}

impl Stats {
    pub fn throughput(&self) -> Option<f64> {
        self.items.map(|n| n / self.mean)
    }
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: Duration::from_millis(300),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

impl BenchOpts {
    /// A faster profile for CI/`--quick` runs.
    pub fn quick() -> Self {
        BenchOpts {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(400),
            min_iters: 3,
            max_iters: 2_000,
        }
    }

    /// Pick quick vs default from the `POGO_BENCH_QUICK` env var.
    pub fn from_env() -> Self {
        if std::env::var("POGO_BENCH_QUICK").is_ok() {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Run one benchmark: `f` is called once per iteration.
pub fn bench(name: &str, opts: BenchOpts, mut f: impl FnMut()) -> Stats {
    bench_with_items(name, opts, None, &mut f)
}

/// Run one benchmark with a throughput denominator (items per iteration).
pub fn bench_items(name: &str, opts: BenchOpts, items: f64, mut f: impl FnMut()) -> Stats {
    bench_with_items(name, opts, Some(items), &mut f)
}

fn bench_with_items(
    name: &str,
    opts: BenchOpts,
    items: Option<f64>,
    f: &mut dyn FnMut(),
) -> Stats {
    // Warmup + single-iteration estimate.
    let w = Stopwatch::start();
    let mut warm_iters = 0usize;
    while w.seconds() < opts.warmup.as_secs_f64() && warm_iters < opts.max_iters {
        f();
        warm_iters += 1;
    }
    let est = (w.seconds() / warm_iters.max(1) as f64).max(1e-9);
    let target =
        ((opts.budget.as_secs_f64() / est) as usize).clamp(opts.min_iters, opts.max_iters);

    let mut samples = Vec::with_capacity(target);
    for _ in 0..target {
        let s = Stopwatch::start();
        f();
        samples.push(s.seconds());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Stats {
        name: name.to_string(),
        iters: n,
        mean,
        stddev: var.sqrt(),
        p50: samples[n / 2],
        p99: samples[(n * 99 / 100).min(n - 1)],
        min: samples[0],
        max: samples[n - 1],
        items,
    }
}

/// Pretty-print a block of results as an aligned table.
pub fn print_table(title: &str, stats: &[Stats]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12} {:>14}",
        "benchmark", "iters", "mean", "p50", "p99", "throughput"
    );
    for s in stats {
        let tput = match s.throughput() {
            Some(t) if t >= 1e6 => format!("{:.2} M/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("{:.2} k/s", t / 1e3),
            Some(t) => format!("{t:.2} /s"),
            None => "-".to_string(),
        };
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12} {:>14}",
            s.name,
            s.iters,
            crate::util::fmt_duration(s.mean),
            crate::util::fmt_duration(s.p50),
            crate::util::fmt_duration(s.p99),
            tput
        );
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Builder for a machine-readable `BENCH_*.json` report.
///
/// Every report shares the same envelope — a `unit` string naming the
/// measurement convention and the worker `threads` count — plus
/// report-specific fields added with [`BenchReport::field`]. Keys are
/// emitted sorted (the underlying [`Json::Obj`] is a `BTreeMap`), exactly
/// as the pre-builder writers did, so adopting the builder changed no
/// bytes in any existing report.
///
/// [`Json::Obj`]: crate::util::json::Json
#[derive(Clone, Debug)]
pub struct BenchReport {
    fields: std::collections::BTreeMap<String, crate::util::json::Json>,
}

impl BenchReport {
    /// Start a report: the `unit` field plus the shared `threads` field.
    pub fn new(unit: &str) -> Self {
        use crate::util::json::Json;
        let mut fields = std::collections::BTreeMap::new();
        fields.insert("unit".to_string(), Json::str(unit));
        fields.insert(
            "threads".to_string(),
            Json::num(crate::util::pool::num_threads() as f64),
        );
        BenchReport { fields }
    }

    /// Add (or replace) one top-level field.
    pub fn field(mut self, key: &str, value: crate::util::json::Json) -> Self {
        self.fields.insert(key.to_string(), value);
        self
    }

    /// The report as a JSON object.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::Obj(self.fields.clone())
    }

    /// Write the report to `default_path`, honoring the `env_var`
    /// redirect (CI points these at the workspace root before uploading
    /// artifacts). Returns the path actually written.
    pub fn write(
        &self,
        env_var: &str,
        default_path: &std::path::Path,
    ) -> std::io::Result<std::path::PathBuf> {
        let path = resolve_bench_path(env_var, default_path)?;
        std::fs::write(&path, self.to_json().to_string_pretty() + "\n")?;
        Ok(path)
    }
}

/// One batched-vs-loop scalability measurement (a `BENCH_scale.json` row).
#[derive(Clone, Debug)]
pub struct ScaleRecord {
    /// Engine-qualified label, e.g. `POGO[batched]`.
    pub label: String,
    /// Group size B.
    pub batch: usize,
    /// Mean per-matrix step cost, microseconds.
    pub us_per_matrix: f64,
}

/// Machine-readable scalability report. `speedups` maps each measured B
/// to the batched-over-loop throughput ratio (`>1` = batched faster);
/// that map is what CI's `bench-smoke` job gates on.
pub fn scale_json(records: &[ScaleRecord], speedups: &[(usize, f64)]) -> crate::util::json::Json {
    scale_report(records, speedups).to_json()
}

fn scale_report(records: &[ScaleRecord], speedups: &[(usize, f64)]) -> BenchReport {
    use crate::util::json::Json;
    let recs = records.iter().map(|r| {
        Json::obj(vec![
            ("label", Json::str(r.label.clone())),
            ("batch", Json::num(r.batch as f64)),
            ("us_per_matrix", Json::num(r.us_per_matrix)),
        ])
    });
    let speedup_map: std::collections::BTreeMap<String, Json> = speedups
        .iter()
        .map(|&(b, s)| (b.to_string(), Json::num(s)))
        .collect();
    BenchReport::new("us_per_matrix_step")
        .field("records", Json::arr(recs))
        .field("speedup_batched_vs_loop", Json::Obj(speedup_map))
}

/// Resolve where a BENCH_*.json report lands: `env_var` redirects the
/// output wherever the caller's environment wants it (CI points it at the
/// workspace root before uploading the artifact), otherwise
/// `default_path`. Parent directories are created.
fn resolve_bench_path(
    env_var: &str,
    default_path: &std::path::Path,
) -> std::io::Result<std::path::PathBuf> {
    let path = match std::env::var(env_var) {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => default_path.to_path_buf(),
    };
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(path)
}

/// Write a batched-vs-loop report to `default_path` (redirect: `env_var`).
/// Every emitter routes through here so the format and the redirect
/// cannot drift. Returns the path actually written.
pub fn write_bench_json(
    env_var: &str,
    default_path: &std::path::Path,
    records: &[ScaleRecord],
    speedups: &[(usize, f64)],
) -> std::io::Result<std::path::PathBuf> {
    scale_report(records, speedups).write(env_var, default_path)
}

/// `BENCH_scale.json` (real Fig. 1 sweep; redirect: `POGO_BENCH_JSON`).
/// Shared by `cargo bench --bench step_micro` and `pogo run scale`.
pub fn write_scale_json(
    default_path: &std::path::Path,
    records: &[ScaleRecord],
    speedups: &[(usize, f64)],
) -> std::io::Result<std::path::PathBuf> {
    write_bench_json("POGO_BENCH_JSON", default_path, records, speedups)
}

/// `BENCH_born.json` (complex Fig. 8 unitary batched-vs-loop race;
/// redirect: `POGO_BENCH_JSON_BORN`). Shared by
/// `cargo bench --bench fig8_born` and `pogo run born`.
pub fn write_born_json(
    default_path: &std::path::Path,
    records: &[ScaleRecord],
    speedups: &[(usize, f64)],
) -> std::io::Result<std::path::PathBuf> {
    write_bench_json("POGO_BENCH_JSON_BORN", default_path, records, speedups)
}

/// One row of the serve-daemon load benchmark (`BENCH_serve.json`):
/// end-to-end job throughput and latency at one client concurrency, for
/// both client styles — v1 polling and the v2 SSE streaming consumer.
#[derive(Clone, Debug)]
pub struct ServeLoadRow {
    /// Concurrent clients submitting jobs.
    pub clients: usize,
    /// Total jobs completed at this concurrency.
    pub jobs: usize,
    /// Jobs completed per wall-clock second (all clients together).
    pub jobs_per_s: f64,
    /// Median submit→done latency (polling client), milliseconds.
    pub p50_ms: f64,
    /// 95th-percentile submit→done latency (polling client), ms.
    pub p95_ms: f64,
    /// Median submit→terminal-SSE-event latency (streaming client), ms.
    pub stream_p50_ms: f64,
    /// 95th-percentile streaming-client latency, ms.
    pub stream_p95_ms: f64,
}

/// Machine-readable serve load report. CI's `serve-smoke` job gates on
/// this file being well-formed (rows present, positive throughput).
pub fn serve_json(rows: &[ServeLoadRow]) -> crate::util::json::Json {
    serve_report(rows).to_json()
}

fn serve_report(rows: &[ServeLoadRow]) -> BenchReport {
    use crate::util::json::Json;
    BenchReport::new("jobs_per_s_and_latency_ms").field(
        "rows",
        Json::arr(rows.iter().map(|r| {
            Json::obj(vec![
                ("clients", Json::num(r.clients as f64)),
                ("jobs", Json::num(r.jobs as f64)),
                ("jobs_per_s", Json::num(r.jobs_per_s)),
                ("p50_ms", Json::num(r.p50_ms)),
                ("p95_ms", Json::num(r.p95_ms)),
                ("stream_p50_ms", Json::num(r.stream_p50_ms)),
                ("stream_p95_ms", Json::num(r.stream_p95_ms)),
            ])
        })),
    )
}

/// `BENCH_serve.json` (daemon load generator; redirect:
/// `POGO_BENCH_JSON_SERVE`). Emitted by `cargo bench --bench serve_load`.
pub fn write_serve_json(
    default_path: &std::path::Path,
    rows: &[ServeLoadRow],
) -> std::io::Result<std::path::PathBuf> {
    serve_report(rows).write("POGO_BENCH_JSON_SERVE", default_path)
}

/// One row of the federation benchmark (`BENCH_front.json`): end-to-end
/// v2 job throughput and latency at one client concurrency, measured
/// twice — through a `pogo front` door and directly against a backend —
/// so the report quantifies the proxy hop.
#[derive(Clone, Debug)]
pub struct FrontLoadRow {
    /// Concurrent clients submitting jobs.
    pub clients: usize,
    /// Total jobs completed at this concurrency (per path).
    pub jobs: usize,
    /// Jobs/s through the front door.
    pub front_jobs_per_s: f64,
    /// Median submit→done latency through the front, milliseconds.
    pub front_p50_ms: f64,
    /// 95th-percentile latency through the front, milliseconds.
    pub front_p95_ms: f64,
    /// Jobs/s straight against one backend (no front hop).
    pub direct_jobs_per_s: f64,
    /// Median direct latency, milliseconds.
    pub direct_p50_ms: f64,
    /// 95th-percentile direct latency, milliseconds.
    pub direct_p95_ms: f64,
}

/// Machine-readable federation load report. CI's `front-smoke` job gates
/// on this file being well-formed (rows present, positive throughput).
pub fn front_json(rows: &[FrontLoadRow]) -> crate::util::json::Json {
    front_report(rows).to_json()
}

fn front_report(rows: &[FrontLoadRow]) -> BenchReport {
    use crate::util::json::Json;
    BenchReport::new("jobs_per_s_and_latency_ms").field(
        "rows",
        Json::arr(rows.iter().map(|r| {
            Json::obj(vec![
                ("clients", Json::num(r.clients as f64)),
                ("jobs", Json::num(r.jobs as f64)),
                ("front_jobs_per_s", Json::num(r.front_jobs_per_s)),
                ("front_p50_ms", Json::num(r.front_p50_ms)),
                ("front_p95_ms", Json::num(r.front_p95_ms)),
                ("direct_jobs_per_s", Json::num(r.direct_jobs_per_s)),
                ("direct_p50_ms", Json::num(r.direct_p50_ms)),
                ("direct_p95_ms", Json::num(r.direct_p95_ms)),
            ])
        })),
    )
}

/// `BENCH_front.json` (federated front-door load; redirect:
/// `POGO_BENCH_JSON_FRONT`). Emitted by `cargo bench --bench front_load`.
pub fn write_front_json(
    default_path: &std::path::Path,
    rows: &[FrontLoadRow],
) -> std::io::Result<std::path::PathBuf> {
    front_report(rows).write("POGO_BENCH_JSON_FRONT", default_path)
}

/// One row of the artifact I/O benchmark (`BENCH_artifact.json`): how
/// fast one artifact operation (`seal`, `encode`, `decode`, `verify`,
/// `store`) moves one payload size.
#[derive(Clone, Debug)]
pub struct ArtifactIoRow {
    /// Operation name.
    pub op: String,
    /// Payload size driven through the operation, MiB.
    pub payload_mb: f64,
    /// Mean wall time per operation, milliseconds.
    pub ms: f64,
    /// Payload throughput, MiB per second.
    pub mb_per_s: f64,
}

/// Machine-readable artifact I/O report. CI's `serve-smoke` job gates on
/// this file being well-formed (rows present, positive throughput).
pub fn artifact_json(rows: &[ArtifactIoRow]) -> crate::util::json::Json {
    artifact_report(rows).to_json()
}

fn artifact_report(rows: &[ArtifactIoRow]) -> BenchReport {
    use crate::util::json::Json;
    BenchReport::new("ms_and_mib_per_s").field(
        "rows",
        Json::arr(rows.iter().map(|r| {
            Json::obj(vec![
                ("op", Json::str(r.op.clone())),
                ("payload_mb", Json::num(r.payload_mb)),
                ("ms", Json::num(r.ms)),
                ("mb_per_s", Json::num(r.mb_per_s)),
            ])
        })),
    )
}

/// `BENCH_artifact.json` (artifact seal/verify/store throughput;
/// redirect: `POGO_BENCH_JSON_ARTIFACT`). Emitted by
/// `cargo bench --bench artifact_io`.
pub fn write_artifact_json(
    default_path: &std::path::Path,
    rows: &[ArtifactIoRow],
) -> std::io::Result<std::path::PathBuf> {
    artifact_report(rows).write("POGO_BENCH_JSON_ARTIFACT", default_path)
}

/// One fused-vs-naive step-kernel measurement (a `BENCH_kernels.json`
/// row): one update rule × element type × path, at one `(p, n)` shape and
/// batch size.
#[derive(Clone, Debug)]
pub struct KernelRecord {
    /// Rule × dtype label, e.g. `pogo-f32`.
    pub label: String,
    /// Execution path: `fused` or `naive`.
    pub kernel: String,
    /// Matrix rows p.
    pub p: usize,
    /// Matrix cols n.
    pub n: usize,
    /// Group size B.
    pub batch: usize,
    /// Mean per-matrix step cost, microseconds.
    pub us_per_matrix: f64,
    /// Effective iterate bandwidth: `3·B·p·n·sizeof(elem)` bytes (read X,
    /// read G, write X) over the mean step time, GiB/s.
    pub gb_per_s: f64,
}

/// Machine-readable step-kernel report. `selected` names the arch
/// microkernel the run dispatched to (`avx2` / `neon` / `portable`);
/// `speedups` maps `"pxn@B"` keys to the fused-over-naive throughput
/// ratio — CI's `bench-smoke` job gates on `"16x16@4096"` ≥ 1.
pub fn kernels_json(
    selected: &str,
    records: &[KernelRecord],
    speedups: &[(String, f64)],
) -> crate::util::json::Json {
    kernels_report(selected, records, speedups).to_json()
}

fn kernels_report(
    selected: &str,
    records: &[KernelRecord],
    speedups: &[(String, f64)],
) -> BenchReport {
    use crate::util::json::Json;
    let recs = records.iter().map(|r| {
        Json::obj(vec![
            ("label", Json::str(r.label.clone())),
            ("kernel", Json::str(r.kernel.clone())),
            ("shape", Json::str(format!("{}x{}", r.p, r.n))),
            ("batch", Json::num(r.batch as f64)),
            ("us_per_matrix", Json::num(r.us_per_matrix)),
            ("gb_per_s", Json::num(r.gb_per_s)),
        ])
    });
    let speedup_map: std::collections::BTreeMap<String, Json> = speedups
        .iter()
        .map(|(k, s)| (k.clone(), Json::num(*s)))
        .collect();
    BenchReport::new("us_per_matrix_step")
        .field("kernel", Json::str(selected))
        .field("records", Json::arr(recs))
        .field("speedup_fused_vs_naive", Json::Obj(speedup_map))
}

/// `BENCH_kernels.json` (fused vs naive step-kernel race; redirect:
/// `POGO_BENCH_JSON_KERNELS`). Emitted by
/// `cargo bench --bench step_kernels`.
pub fn write_kernels_json(
    default_path: &std::path::Path,
    selected: &str,
    records: &[KernelRecord],
    speedups: &[(String, f64)],
) -> std::io::Result<std::path::PathBuf> {
    kernels_report(selected, records, speedups).write("POGO_BENCH_JSON_KERNELS", default_path)
}

/// One raw pool-dispatch latency measurement (a `BENCH_pool.json`
/// `dispatch` row): the cost of waking the pool, running `shards` empty
/// shards, and hitting the completion barrier — pure orchestration
/// overhead, no compute.
#[derive(Clone, Debug)]
pub struct DispatchRecord {
    /// Pool backend: `resident` or `spawn`.
    pub pool: String,
    /// Shards per dispatch.
    pub shards: usize,
    /// Mean wall time per dispatch, nanoseconds.
    pub ns_per_dispatch: f64,
}

/// One end-to-end fused-step measurement under one pool backend (a
/// `BENCH_pool.json` `step` row).
#[derive(Clone, Debug)]
pub struct PoolRecord {
    /// Pool backend: `resident` or `spawn`.
    pub pool: String,
    /// Rule × dtype label, e.g. `pogo-f32`.
    pub label: String,
    /// Matrix rows p.
    pub p: usize,
    /// Matrix cols n.
    pub n: usize,
    /// Group size B.
    pub batch: usize,
    /// Mean whole-batch step cost, microseconds.
    pub us_per_step: f64,
}

/// Machine-readable resident-vs-spawn pool report. `speedups` maps
/// `"pxn@B"` keys to the spawn-over-resident step-time ratio (`>1` =
/// resident faster) — CI's `bench-smoke` job gates on `"16x16@4096"` ≥ 1.
pub fn pool_json(
    dispatch: &[DispatchRecord],
    records: &[PoolRecord],
    speedups: &[(String, f64)],
) -> crate::util::json::Json {
    pool_report(dispatch, records, speedups).to_json()
}

fn pool_report(
    dispatch: &[DispatchRecord],
    records: &[PoolRecord],
    speedups: &[(String, f64)],
) -> BenchReport {
    use crate::util::json::Json;
    let disp = dispatch.iter().map(|d| {
        Json::obj(vec![
            ("pool", Json::str(d.pool.clone())),
            ("shards", Json::num(d.shards as f64)),
            ("ns_per_dispatch", Json::num(d.ns_per_dispatch)),
        ])
    });
    let recs = records.iter().map(|r| {
        Json::obj(vec![
            ("pool", Json::str(r.pool.clone())),
            ("label", Json::str(r.label.clone())),
            ("shape", Json::str(format!("{}x{}", r.p, r.n))),
            ("batch", Json::num(r.batch as f64)),
            ("us_per_step", Json::num(r.us_per_step)),
        ])
    });
    let speedup_map: std::collections::BTreeMap<String, Json> = speedups
        .iter()
        .map(|(k, s)| (k.clone(), Json::num(*s)))
        .collect();
    BenchReport::new("ns_per_dispatch_and_us_per_step")
        .field("dispatch", Json::arr(disp))
        .field("records", Json::arr(recs))
        .field("speedup_resident_vs_spawn", Json::Obj(speedup_map))
}

/// `BENCH_pool.json` (resident-vs-spawn dispatch latency race; redirect:
/// `POGO_BENCH_JSON_POOL`). Emitted by `cargo bench --bench pool_dispatch`.
pub fn write_pool_json(
    default_path: &std::path::Path,
    dispatch: &[DispatchRecord],
    records: &[PoolRecord],
    speedups: &[(String, f64)],
) -> std::io::Result<std::path::PathBuf> {
    pool_report(dispatch, records, speedups).write("POGO_BENCH_JSON_POOL", default_path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_sane_stats() {
        let s = bench("noop-ish", BenchOpts::quick(), || {
            black_box((0..100).sum::<usize>());
        });
        assert!(s.iters >= 3);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.p50 && s.p50 <= s.max);
        assert!(s.p99 <= s.max + 1e-12);
    }

    #[test]
    fn scale_json_shape() {
        let records = vec![
            ScaleRecord { label: "POGO[loop]".into(), batch: 64, us_per_matrix: 2.0 },
            ScaleRecord { label: "POGO[batched]".into(), batch: 64, us_per_matrix: 0.5 },
        ];
        let j = scale_json(&records, &[(64, 4.0)]);
        assert_eq!(j.get("unit").as_str(), Some("us_per_matrix_step"));
        assert_eq!(j.get("records").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("speedup_batched_vs_loop").get("64").as_f64(), Some(4.0));
        // Round-trips through the in-crate parser (what CI's jq reads).
        let back = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn serve_json_shape() {
        let rows = vec![ServeLoadRow {
            clients: 4,
            jobs: 8,
            jobs_per_s: 12.5,
            p50_ms: 40.0,
            p95_ms: 90.0,
            stream_p50_ms: 35.0,
            stream_p95_ms: 80.0,
        }];
        let j = serve_json(&rows);
        assert_eq!(j.get("unit").as_str(), Some("jobs_per_s_and_latency_ms"));
        let arr = j.get("rows").as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("clients").as_usize(), Some(4));
        assert_eq!(arr[0].get("jobs_per_s").as_f64(), Some(12.5));
        assert_eq!(arr[0].get("stream_p95_ms").as_f64(), Some(80.0));
        // Round-trips through the in-crate parser (what CI's jq reads).
        let back = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn artifact_json_shape() {
        let rows = vec![ArtifactIoRow {
            op: "seal".into(),
            payload_mb: 8.0,
            ms: 12.5,
            mb_per_s: 640.0,
        }];
        let j = artifact_json(&rows);
        assert_eq!(j.get("unit").as_str(), Some("ms_and_mib_per_s"));
        let arr = j.get("rows").as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("op").as_str(), Some("seal"));
        assert_eq!(arr[0].get("payload_mb").as_f64(), Some(8.0));
        assert_eq!(arr[0].get("mb_per_s").as_f64(), Some(640.0));
        // Round-trips through the in-crate parser (what CI's jq reads).
        let back = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn bench_report_envelope_and_fields() {
        use crate::util::json::Json;
        let j = BenchReport::new("widgets_per_s")
            .field("rows", Json::arr([Json::num(1.0)]))
            .to_json();
        assert_eq!(j.get("unit").as_str(), Some("widgets_per_s"));
        assert_eq!(
            j.get("threads").as_usize(),
            Some(crate::util::pool::num_threads())
        );
        assert_eq!(j.get("rows").as_arr().unwrap().len(), 1);
        // Repeated keys replace, not duplicate.
        let j2 = BenchReport::new("a").field("unit", Json::str("b")).to_json();
        assert_eq!(j2.get("unit").as_str(), Some("b"));
    }

    #[test]
    fn kernels_json_shape() {
        let records = vec![
            KernelRecord {
                label: "pogo-f32".into(),
                kernel: "fused".into(),
                p: 16,
                n: 16,
                batch: 4096,
                us_per_matrix: 0.8,
                gb_per_s: 12.0,
            },
            KernelRecord {
                label: "pogo-f32".into(),
                kernel: "naive".into(),
                p: 16,
                n: 16,
                batch: 4096,
                us_per_matrix: 2.0,
                gb_per_s: 4.8,
            },
        ];
        let j = kernels_json("portable", &records, &[("16x16@4096".to_string(), 2.5)]);
        assert_eq!(j.get("unit").as_str(), Some("us_per_matrix_step"));
        assert_eq!(j.get("kernel").as_str(), Some("portable"));
        let recs = j.get("records").as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("shape").as_str(), Some("16x16"));
        assert_eq!(recs[0].get("kernel").as_str(), Some("fused"));
        assert_eq!(recs[0].get("batch").as_usize(), Some(4096));
        assert_eq!(recs[0].get("gb_per_s").as_f64(), Some(12.0));
        assert_eq!(j.get("speedup_fused_vs_naive").get("16x16@4096").as_f64(), Some(2.5));
        // Round-trips through the in-crate parser (what CI's jq reads).
        let back = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn pool_json_shape() {
        let dispatch = vec![
            DispatchRecord { pool: "resident".into(), shards: 4, ns_per_dispatch: 900.0 },
            DispatchRecord { pool: "spawn".into(), shards: 4, ns_per_dispatch: 24_000.0 },
        ];
        let records = vec![
            PoolRecord {
                pool: "resident".into(),
                label: "pogo-f32".into(),
                p: 16,
                n: 16,
                batch: 4096,
                us_per_step: 600.0,
            },
            PoolRecord {
                pool: "spawn".into(),
                label: "pogo-f32".into(),
                p: 16,
                n: 16,
                batch: 4096,
                us_per_step: 780.0,
            },
        ];
        let j = pool_json(&dispatch, &records, &[("16x16@4096".to_string(), 1.3)]);
        assert_eq!(j.get("unit").as_str(), Some("ns_per_dispatch_and_us_per_step"));
        let disp = j.get("dispatch").as_arr().unwrap();
        assert_eq!(disp.len(), 2);
        assert_eq!(disp[0].get("pool").as_str(), Some("resident"));
        assert_eq!(disp[0].get("shards").as_usize(), Some(4));
        assert_eq!(disp[1].get("ns_per_dispatch").as_f64(), Some(24_000.0));
        let recs = j.get("records").as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("shape").as_str(), Some("16x16"));
        assert_eq!(recs[0].get("us_per_step").as_f64(), Some(600.0));
        assert_eq!(j.get("speedup_resident_vs_spawn").get("16x16@4096").as_f64(), Some(1.3));
        // Round-trips through the in-crate parser (what CI's jq reads).
        let back = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn throughput_computed() {
        let s = bench_items("items", BenchOpts::quick(), 1000.0, || {
            black_box((0..100).sum::<usize>());
        });
        assert!(s.throughput().unwrap() > 0.0);
    }
}
