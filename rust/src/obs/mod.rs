//! Zero-dependency observability: latency histograms, span tracing, and
//! the `POGO_OBS` kill switch.
//!
//! Two instruments, one contract:
//!
//! - [`hist`] — log-linear latency histograms (lock-free atomics, fixed
//!   1-2-5 bucket ladder) behind a crate-wide family registry, exported
//!   in Prometheus text format from the daemon's `/metrics`.
//! - [`trace`] — per-job flight recorder: bounded span buffers over
//!   `Instant`, rendered as a span tree (`GET /v2/jobs/:id/trace`) or as
//!   Chrome trace-event JSON (`pogo trace`).
//!
//! **Overhead contract.** Hot paths (the batched step, pool dispatch)
//! check [`enabled`] — one relaxed atomic load — before touching a clock,
//! and record through cached `&'static Hist` handles: atomics only, no
//! locks, no allocation in steady state. Span recording happens at job
//! lifecycle boundaries and sampled step windows (every k steps), never
//! per step. `POGO_OBS=off` turns every instrument into that single
//! atomic load; `tests/alloc_steady_state.rs` pins the off path (and the
//! cached-handle on path) allocation-free.

pub mod hist;
pub mod trace;

pub use hist::{render_prometheus, Family, Hist, FAMILIES};
pub use trace::JobTrace;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// In-process override: 0 = unset (env decides), 1 = on, 2 = off.
static OBS_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Is observability recording on? On by default; `POGO_OBS=off` (or `0`
/// or `false`) disables it. The env var is read once; tests and benches
/// flip [`set_enabled`] instead.
pub fn enabled() -> bool {
    match OBS_OVERRIDE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            static FROM_ENV: OnceLock<bool> = OnceLock::new();
            *FROM_ENV.get_or_init(|| {
                !matches!(
                    std::env::var("POGO_OBS").ok().as_deref(),
                    Some("off") | Some("0") | Some("false")
                )
            })
        }
    }
}

/// Force observability on/off in-process (`None` returns control to the
/// `POGO_OBS` env var). For tests and benches.
pub fn set_enabled(on: Option<bool>) {
    OBS_OVERRIDE.store(match on { Some(true) => 1, Some(false) => 2, None => 0 }, Ordering::Relaxed);
}

/// Serializes unit tests that flip process-global overrides (the obs
/// switch, pool mode, thread count). Cargo runs a crate's tests on
/// parallel threads in one process, so every test that calls
/// [`set_enabled`] or the pool overrides must hold this first.
#[cfg(test)]
pub(crate) static TEST_OVERRIDE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn override_wins_and_resets() {
        let _g = TEST_OVERRIDE_LOCK.lock().unwrap();
        set_enabled(Some(false));
        assert!(!enabled());
        set_enabled(Some(true));
        assert!(enabled());
        set_enabled(None);
        // Whatever the env says, the call must not panic.
        let _ = enabled();
    }
}
