//! Per-job flight recorder: a bounded buffer of completed spans over one
//! `Instant` origin.
//!
//! A [`JobTrace`] is created when a job is admitted (its origin) and
//! shared by everyone who touches the job afterwards: the admission path
//! records `admit`, the worker records `queued`/`run`/`job` around the
//! lifecycle, and the engine-side hooks in `serve/job.rs` record the
//! `build`/`resume`/`steps`/`checkpoint` segments inside the run. Spans
//! carry an explicit nesting `depth` instead of a thread-local stack —
//! a job's lifecycle is sequential but crosses threads (HTTP handler →
//! queue → worker), so stack-based scoping would lie about parentage.
//!
//! The buffer is bounded: lifecycle spans (depth ≤ 1) are always kept,
//! inner spans are dropped (and counted) once [`SPAN_CAP`] is reached, so
//! a million-step job cannot grow its trace without bound.
//!
//! Two renderings: [`JobTrace::tree_json`] nests spans by depth with
//! self/total times (the `GET /v2/jobs/:id/trace` payload), and
//! [`JobTrace::chrome_json`] emits the Chrome trace-event array
//! (`ph: "X"` complete events) that `pogo trace` writes for
//! chrome://tracing / perfetto.

use crate::util::json::Json;
use std::sync::Mutex;
use std::time::Instant;

/// Maximum retained spans per job; inner spans past this are counted in
/// `dropped` instead of stored.
pub const SPAN_CAP: usize = 512;

#[derive(Clone, Debug)]
struct SpanRec {
    name: &'static str,
    start_us: u64,
    dur_us: u64,
    depth: u32,
    /// For sampled step-window spans: the covered `[start, end)` steps.
    steps: Option<(u64, u64)>,
}

struct TraceInner {
    spans: Vec<SpanRec>,
    dropped: u64,
}

/// One job's span recorder. Cheap to share (`Arc<JobTrace>`); recording
/// takes a short mutex — acceptable because spans are recorded at
/// lifecycle boundaries and sampled step windows, never per step.
pub struct JobTrace {
    origin: Instant,
    inner: Mutex<TraceInner>,
}

impl JobTrace {
    pub fn new() -> JobTrace {
        JobTrace {
            origin: Instant::now(),
            inner: Mutex::new(TraceInner { spans: Vec::new(), dropped: 0 }),
        }
    }

    /// Microseconds since this trace's origin (span timestamps).
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros().min(u64::MAX as u128) as u64
    }

    /// Record one completed span. `depth` 0 is the root; children carry
    /// `parent depth + 1`. Inner spans (depth ≥ 2) are dropped once the
    /// buffer holds [`SPAN_CAP`] spans.
    pub fn record_span(&self, name: &'static str, start_us: u64, dur_us: u64, depth: u32) {
        self.record_span_full(name, start_us, dur_us, depth, None);
    }

    /// [`record_span`](Self::record_span) with a step-window annotation.
    pub fn record_span_full(
        &self,
        name: &'static str,
        start_us: u64,
        dur_us: u64,
        depth: u32,
        steps: Option<(u64, u64)>,
    ) {
        let mut inner = self.inner.lock().unwrap();
        if inner.spans.len() >= SPAN_CAP && depth >= 2 {
            inner.dropped += 1;
            return;
        }
        inner.spans.push(SpanRec { name, start_us, dur_us, depth, steps });
    }

    /// Number of recorded spans.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans dropped to the buffer cap.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// The span tree: `{"spans": [...], "span_count": n, "dropped": d}`
    /// where each node is `{"name", "start_us", "dur_us", "self_us",
    /// "children"}` (plus `"steps": [a, b]` on sampled step windows).
    /// `self_us` is the span's duration minus its direct children's.
    pub fn tree_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut order: Vec<usize> = (0..inner.spans.len()).collect();
        // Sort by start time, shallower first on ties: parents (which are
        // recorded at completion, i.e. after their children) come before
        // their children in render order.
        order.sort_by_key(|&i| (inner.spans[i].start_us, inner.spans[i].depth));

        // Parent of a span = the most recent earlier span one level up.
        // A job's recording is sequential, so this reconstruction is exact.
        let mut child_dur: Vec<u64> = vec![0; order.len()];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); order.len()];
        let mut roots: Vec<usize> = Vec::new();
        let mut last_at_depth: Vec<Option<usize>> = Vec::new();
        for (pos, &i) in order.iter().enumerate() {
            let s = &inner.spans[i];
            let d = s.depth as usize;
            if last_at_depth.len() <= d {
                last_at_depth.resize(d + 1, None);
            }
            last_at_depth[d] = Some(pos);
            last_at_depth.truncate(d + 1);
            match d.checked_sub(1).and_then(|pd| last_at_depth.get(pd).copied().flatten()) {
                Some(parent) => {
                    children[parent].push(pos);
                    child_dur[parent] += s.dur_us;
                }
                None => roots.push(pos),
            }
        }
        // Children always sort after their parent (later start, or equal
        // start at greater depth), so a reverse pass builds leaf-to-root.
        let mut nodes: Vec<Json> = (0..order.len()).map(|_| Json::Null).collect();
        for pos in (0..order.len()).rev() {
            let s = &inner.spans[order[pos]];
            let kids: Vec<Json> = children[pos]
                .iter()
                .map(|&c| std::mem::replace(&mut nodes[c], Json::Null))
                .collect();
            let mut fields = vec![
                ("name", Json::str(s.name)),
                ("start_us", Json::num(s.start_us as f64)),
                ("dur_us", Json::num(s.dur_us as f64)),
                ("self_us", Json::num(s.dur_us.saturating_sub(child_dur[pos]) as f64)),
            ];
            if let Some((a, b)) = s.steps {
                fields.push(("steps", Json::arr(vec![Json::num(a as f64), Json::num(b as f64)])));
            }
            fields.push(("children", Json::arr(kids)));
            nodes[pos] = Json::obj(fields);
        }
        let root_nodes: Vec<Json> =
            roots.iter().map(|&r| std::mem::replace(&mut nodes[r], Json::Null)).collect();
        Json::obj(vec![
            ("spans", Json::arr(root_nodes)),
            ("span_count", Json::num(inner.spans.len() as f64)),
            ("dropped", Json::num(inner.dropped as f64)),
        ])
    }

    /// Chrome trace-event JSON: a flat array of `ph: "X"` complete
    /// events (µs timestamps), loadable by chrome://tracing and perfetto.
    pub fn chrome_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut order: Vec<usize> = (0..inner.spans.len()).collect();
        order.sort_by_key(|&i| (inner.spans[i].start_us, inner.spans[i].depth));
        let events: Vec<Json> = order
            .iter()
            .map(|&i| {
                let s = &inner.spans[i];
                let name = match s.steps {
                    Some((a, b)) => format!("{} {a}..{b}", s.name),
                    None => s.name.to_string(),
                };
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("cat", Json::str("job")),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(s.start_us as f64)),
                    ("dur", Json::num(s.dur_us as f64)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(1.0)),
                ])
            })
            .collect();
        Json::arr(events)
    }
}

impl Default for JobTrace {
    fn default() -> JobTrace {
        JobTrace::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic lifecycle: job(0..100) > admit(0..2), queued(2..10),
    /// run(10..100) > steps(12..95) > two sampled windows.
    fn lifecycle() -> JobTrace {
        let t = JobTrace::new();
        t.record_span("admit", 0, 2, 1);
        t.record_span("queued", 2, 8, 1);
        t.record_span_full("steps", 12, 40, 3, Some((0, 8)));
        t.record_span_full("steps", 52, 43, 3, Some((8, 16)));
        t.record_span("steps", 12, 83, 2);
        t.record_span("run", 10, 90, 1);
        t.record_span("job", 0, 100, 0);
        t
    }

    #[test]
    fn tree_nests_by_depth_and_computes_self_time() {
        let j = lifecycle().tree_json();
        assert_eq!(j.get("span_count").as_usize(), Some(7));
        assert_eq!(j.get("dropped").as_usize(), Some(0));
        let roots = j.get("spans").as_arr().unwrap();
        assert_eq!(roots.len(), 1);
        let job = &roots[0];
        assert_eq!(job.get("name").as_str(), Some("job"));
        // job's children: admit, queued, run (in start order).
        let kids = job.get("children").as_arr().unwrap();
        let names: Vec<&str> = kids.iter().map(|k| k.get("name").as_str().unwrap()).collect();
        assert_eq!(names, ["admit", "queued", "run"]);
        // self = 100 - (2 + 8 + 90) = 0.
        assert_eq!(job.get("self_us").as_usize(), Some(0));
        let run = &kids[2];
        let run_kids = run.get("children").as_arr().unwrap();
        assert_eq!(run_kids.len(), 1);
        let steps = &run_kids[0];
        assert_eq!(steps.get("name").as_str(), Some("steps"));
        let windows = steps.get("children").as_arr().unwrap();
        assert_eq!(windows.len(), 2);
        assert_eq!(windows[1].get("steps").as_arr().unwrap()[1].as_usize(), Some(16));
        // steps self = 83 - 40 - 43 = 0; run self = 90 - 83 = 7.
        assert_eq!(steps.get("self_us").as_usize(), Some(0));
        assert_eq!(run.get("self_us").as_usize(), Some(7));
    }

    #[test]
    fn span_total_at_least_children_self_sum() {
        fn check(node: &Json) {
            let total = node.get("dur_us").as_f64().unwrap();
            let mut child_self = 0.0;
            for k in node.get("children").as_arr().unwrap() {
                child_self += k.get("self_us").as_f64().unwrap();
                check(k);
            }
            assert!(total + 1e-9 >= child_self, "{node:?}");
        }
        let j = lifecycle().tree_json();
        for root in j.get("spans").as_arr().unwrap() {
            check(root);
        }
    }

    #[test]
    fn cap_drops_inner_spans_only() {
        let t = JobTrace::new();
        for i in 0..(SPAN_CAP + 10) {
            t.record_span("inner", i as u64, 1, 3);
        }
        assert_eq!(t.len(), SPAN_CAP);
        assert_eq!(t.dropped(), 10);
        // Lifecycle spans still land past the cap.
        t.record_span("job", 0, 1_000_000, 0);
        assert_eq!(t.len(), SPAN_CAP + 1);
    }

    #[test]
    fn chrome_events_are_complete_events() {
        let j = lifecycle().chrome_json();
        let events = j.as_arr().unwrap();
        assert_eq!(events.len(), 7);
        assert_eq!(events[0].get("ph").as_str(), Some("X"));
        assert_eq!(events[0].get("name").as_str(), Some("job"));
        assert!(events[0].get("dur").as_f64().unwrap() > 0.0);
        // Step windows carry their range in the name.
        assert!(events
            .iter()
            .any(|e| e.get("name").as_str().map(|s| s.contains("8..16")).unwrap_or(false)));
    }
}
