//! Log-linear latency histograms with lock-free recording.
//!
//! A [`Hist`] is a fixed 1-2-5 bucket ladder over microseconds (1 µs to
//! 500 s, 27 finite bounds plus +Inf) backed by relaxed `AtomicU64`
//! counters: recording is a linear scan over 27 integers plus two
//! `fetch_add`s — no locks, no allocation, wait-free. Two histograms on
//! the same ladder are mergeable by adding counters ([`Hist::merge_from`]).
//!
//! A [`Family`] groups histograms under one Prometheus metric name with a
//! fixed set of label *names* and dynamically registered label *values*.
//! Label values must be `&'static str` (routes, kernel names, shape
//! classes — all small closed sets), so series lookup compares pointers
//! and lengths without building keys: after a series' one-time
//! registration, the record path allocates nothing. Hot sites should call
//! [`Family::hist`] once and cache the returned `&'static Hist`.
//!
//! [`render_prometheus`] walks the crate-wide [`FAMILIES`] registry and
//! appends every family in Prometheus text exposition format. The
//! rendered `_count` (and the `+Inf` bucket) is computed by summing the
//! bucket counters, so `+Inf == _count` holds exactly even while other
//! threads record concurrently.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Finite bucket upper bounds in microseconds: a 1-2-5 ladder per decade
/// from 1 µs to 5·10⁸ µs (500 s). Everything slower lands in +Inf.
pub const BOUNDS_US: [u64; 27] = [
    1,
    2,
    5,
    10,
    20,
    50,
    100,
    200,
    500,
    1_000,
    2_000,
    5_000,
    10_000,
    20_000,
    50_000,
    100_000,
    200_000,
    500_000,
    1_000_000,
    2_000_000,
    5_000_000,
    10_000_000,
    20_000_000,
    50_000_000,
    100_000_000,
    200_000_000,
    500_000_000,
];

/// The same bounds as Prometheus `le` strings in *seconds*, precomputed
/// so rendering never formats floats for bucket bounds.
const LE_SECONDS: [&str; 27] = [
    "0.000001", "0.000002", "0.000005", "0.00001", "0.00002", "0.00005", "0.0001", "0.0002",
    "0.0005", "0.001", "0.002", "0.005", "0.01", "0.02", "0.05", "0.1", "0.2", "0.5", "1", "2",
    "5", "10", "20", "50", "100", "200", "500",
];

/// Number of counters: the finite bounds plus the +Inf overflow bucket.
pub const N_BUCKETS: usize = BOUNDS_US.len() + 1;

/// One log-linear histogram. Construction is `const`, so histograms can
/// live in statics; recording is wait-free.
pub struct Hist {
    buckets: [AtomicU64; N_BUCKETS],
    sum_us: AtomicU64,
}

impl Hist {
    pub const fn new() -> Hist {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Hist { buckets: [Z; N_BUCKETS], sum_us: AtomicU64::new(0) }
    }

    /// Record one observation of `us` microseconds. Allocation-free.
    pub fn record_us(&self, us: u64) {
        let mut idx = BOUNDS_US.len();
        for (i, &b) in BOUNDS_US.iter().enumerate() {
            if us <= b {
                idx = i;
                break;
            }
        }
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Record the elapsed time since `t0`.
    pub fn record_since(&self, t0: Instant) {
        self.record(t0.elapsed());
    }

    /// Total observations (sum of all bucket counters).
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Sum of observed durations, in microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Per-bucket (non-cumulative) counts, +Inf last.
    pub fn snapshot(&self) -> [u64; N_BUCKETS] {
        let mut out = [0u64; N_BUCKETS];
        for (dst, src) in out.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        out
    }

    /// Upper bound (µs) of the bucket holding the `q`-quantile of the
    /// recorded observations, by a cumulative walk of the ladder. Returns
    /// `None` when nothing has been recorded; observations in the +Inf
    /// bucket clamp to the last finite bound, so the estimate is always a
    /// usable duration. `q` is clamped to `[0, 1]`.
    pub fn quantile_us(&self, q: f64) -> Option<u64> {
        let snap = self.snapshot();
        let total: u64 = snap.iter().sum();
        if total == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in snap.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Some(BOUNDS_US[i.min(BOUNDS_US.len() - 1)]);
            }
        }
        Some(BOUNDS_US[BOUNDS_US.len() - 1])
    }

    /// Fold another histogram (same ladder by construction) into this one.
    pub fn merge_from(&self, other: &Hist) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            dst.fetch_add(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.sum_us.fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

struct Series {
    labels: Vec<&'static str>,
    hist: &'static Hist,
}

/// A named histogram family with fixed label names and dynamically
/// registered label-value combinations. `Family::new` is `const`; the
/// crate's families live in statics (see [`FAMILIES`]).
pub struct Family {
    name: &'static str,
    help: &'static str,
    label_names: &'static [&'static str],
    series: Mutex<Vec<Series>>,
    /// Cached handle for label-less families (the common hot case).
    unlabeled: OnceLock<&'static Hist>,
}

impl Family {
    pub const fn new(
        name: &'static str,
        help: &'static str,
        label_names: &'static [&'static str],
    ) -> Family {
        Family { name, help, label_names, series: Mutex::new(Vec::new()), unlabeled: OnceLock::new() }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The histogram for one label-value combination, registering it on
    /// first use (the registration allocates once; the handle is
    /// process-lived, so hot sites should cache it).
    pub fn hist(&self, labels: &[&'static str]) -> &'static Hist {
        debug_assert_eq!(labels.len(), self.label_names.len(), "{}", self.name);
        let mut series = self.series.lock().unwrap();
        if let Some(s) = series.iter().find(|s| s.labels == labels) {
            return s.hist;
        }
        let hist: &'static Hist = Box::leak(Box::new(Hist::new()));
        series.push(Series { labels: labels.to_vec(), hist });
        hist
    }

    /// The histogram of a label-less family, cached so steady-state
    /// recording skips the series lock entirely.
    pub fn hist0(&'static self) -> &'static Hist {
        self.unlabeled.get_or_init(|| self.hist(&[]))
    }

    /// Record `us` if observability is on (convenience for cold paths;
    /// hot paths gate on [`super::enabled`] and cache the handle).
    pub fn record_us(&self, labels: &[&'static str], us: u64) {
        if super::enabled() {
            self.hist(labels).record_us(us);
        }
    }

    /// Record the time since `t0` if observability is on.
    pub fn record_since(&self, labels: &[&'static str], t0: Instant) {
        if super::enabled() {
            self.hist(labels).record_since(t0);
        }
    }

    /// Append this family in Prometheus text format. Emits the
    /// `# HELP`/`# TYPE` preamble always, then one
    /// `_bucket`/`_sum`/`_count` block per registered series with
    /// *cumulative* bucket counts.
    pub fn render_into(&self, out: &mut String) {
        out.push_str("# HELP ");
        out.push_str(self.name);
        out.push(' ');
        out.push_str(self.help);
        out.push_str("\n# TYPE ");
        out.push_str(self.name);
        out.push_str(" histogram\n");
        let series = self.series.lock().unwrap();
        for s in series.iter() {
            let mut prefix = String::new();
            for (i, (k, v)) in self.label_names.iter().zip(s.labels.iter()).enumerate() {
                if i > 0 {
                    prefix.push(',');
                }
                prefix.push_str(k);
                prefix.push_str("=\"");
                prefix.push_str(v);
                prefix.push('"');
            }
            let counts = s.hist.snapshot();
            let mut cum = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                cum += c;
                let le = if i < BOUNDS_US.len() { LE_SECONDS[i] } else { "+Inf" };
                if prefix.is_empty() {
                    out.push_str(&format!("{}_bucket{{le=\"{le}\"}} {cum}\n", self.name));
                } else {
                    out.push_str(&format!(
                        "{}_bucket{{{prefix},le=\"{le}\"}} {cum}\n",
                        self.name
                    ));
                }
            }
            let sum_s = s.hist.sum_us() as f64 / 1e6;
            if prefix.is_empty() {
                out.push_str(&format!("{}_sum {sum_s:.6}\n", self.name));
                out.push_str(&format!("{}_count {cum}\n", self.name));
            } else {
                out.push_str(&format!("{}_sum{{{prefix}}} {sum_s:.6}\n", self.name));
                out.push_str(&format!("{}_count{{{prefix}}} {cum}\n", self.name));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The crate's histogram families.
// ---------------------------------------------------------------------------

/// HTTP request duration by normalized route and status class.
pub static HTTP_REQUEST_SECONDS: Family = Family::new(
    "pogo_serve_http_request_duration_seconds",
    "HTTP request duration by normalized route and status class.",
    &["route", "status"],
);

/// Admission → worker claim.
pub static JOB_QUEUE_WAIT_SECONDS: Family = Family::new(
    "pogo_serve_job_queue_wait_seconds",
    "Time from job admission to a worker claiming it.",
    &[],
);

/// Worker claim → terminal state.
pub static JOB_RUN_SECONDS: Family = Family::new(
    "pogo_serve_job_run_seconds",
    "Time from worker claim to the job reaching a terminal state.",
    &[],
);

/// Checkpoint save/restore wall time.
pub static CHECKPOINT_IO_SECONDS: Family = Family::new(
    "pogo_checkpoint_io_seconds",
    "Checkpoint save/restore wall time by operation.",
    &["op"],
);

/// One batched optimizer step, by engine, kernel and shape class.
pub static STEP_SECONDS: Family = Family::new(
    "pogo_step_duration_seconds",
    "Batched optimizer step duration by engine, kernel and shape class.",
    &["engine", "kernel", "shape"],
);

/// One `OptimSession::apply` (all shape groups of one training step).
pub static SESSION_APPLY_SECONDS: Family = Family::new(
    "pogo_session_apply_seconds",
    "OptimSession apply duration (all shape groups of one step).",
    &[],
);

/// Wait to acquire the resident pool's dispatch lock.
pub static POOL_DISPATCH_WAIT_SECONDS: Family = Family::new(
    "pogo_pool_dispatch_wait_seconds",
    "Wait to acquire the worker pool dispatch lock.",
    &[],
);

/// One parallel region, dispatch to barrier.
pub static POOL_RUN_SECONDS: Family = Family::new(
    "pogo_pool_run_seconds",
    "Parallel region wall time from dispatch to barrier completion.",
    &[],
);

/// Every family `/metrics` exports, in render order.
pub static FAMILIES: &[&Family] = &[
    &HTTP_REQUEST_SECONDS,
    &JOB_QUEUE_WAIT_SECONDS,
    &JOB_RUN_SECONDS,
    &CHECKPOINT_IO_SECONDS,
    &STEP_SECONDS,
    &SESSION_APPLY_SECONDS,
    &POOL_DISPATCH_WAIT_SECONDS,
    &POOL_RUN_SECONDS,
];

/// Append every registered family in Prometheus text format.
pub fn render_prometheus(out: &mut String) {
    for f in FAMILIES {
        f.render_into(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_strictly_increasing() {
        for w in BOUNDS_US.windows(2) {
            assert!(w[0] < w[1], "{w:?}");
        }
        assert_eq!(BOUNDS_US.len(), LE_SECONDS.len());
    }

    #[test]
    fn le_strings_match_bounds() {
        for (&us, le) in BOUNDS_US.iter().zip(LE_SECONDS.iter()) {
            let parsed: f64 = le.parse().unwrap();
            let diff = (parsed - us as f64 / 1e6).abs();
            assert!(diff < 1e-12, "{us} vs {le}");
        }
    }

    #[test]
    fn records_land_in_the_right_bucket() {
        let h = Hist::new();
        h.record_us(0); // below the first bound
        h.record_us(1);
        h.record_us(2);
        h.record_us(3); // -> le=5
        h.record_us(1_000_000); // 1 s exactly
        h.record_us(u64::MAX); // +Inf
        let snap = h.snapshot();
        assert_eq!(snap[0], 2); // 0 and 1
        assert_eq!(snap[1], 1); // 2
        assert_eq!(snap[2], 1); // 3
        assert_eq!(snap[18], 1); // 1 s bound
        assert_eq!(snap[N_BUCKETS - 1], 1); // +Inf
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn merge_adds_counters() {
        let a = Hist::new();
        let b = Hist::new();
        a.record_us(10);
        b.record_us(10);
        b.record_us(99);
        a.merge_from(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.sum_us(), 119);
    }

    #[test]
    fn quantiles_walk_the_cumulative_ladder() {
        let h = Hist::new();
        assert_eq!(h.quantile_us(0.5), None, "empty histogram has no quantiles");
        for _ in 0..9 {
            h.record_us(100);
        }
        h.record_us(2_000_000);
        assert_eq!(h.quantile_us(0.0), Some(100));
        assert_eq!(h.quantile_us(0.5), Some(100));
        assert_eq!(h.quantile_us(0.95), Some(2_000_000));
        assert_eq!(h.quantile_us(1.0), Some(2_000_000));
        // +Inf observations clamp to the last finite bound.
        let inf = Hist::new();
        inf.record_us(u64::MAX);
        assert_eq!(inf.quantile_us(0.5), Some(*BOUNDS_US.last().unwrap()));
    }

    #[test]
    fn family_render_is_cumulative_with_inf_equal_count() {
        static F: Family = Family::new("test_render_seconds", "Test family.", &["k"]);
        let h = F.hist(&["a"]);
        h.record_us(1);
        h.record_us(3);
        h.record_us(7);
        let mut out = String::new();
        F.render_into(&mut out);
        assert!(out.starts_with("# HELP test_render_seconds Test family.\n"));
        assert!(out.contains("# TYPE test_render_seconds histogram\n"));
        // Cumulative and monotone; +Inf == _count.
        let mut last = 0u64;
        let mut inf = None;
        for line in out.lines() {
            if let Some(rest) = line.strip_prefix("test_render_seconds_bucket{") {
                let v: u64 = rest.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= last, "{line}");
                last = v;
                if rest.contains("le=\"+Inf\"") {
                    inf = Some(v);
                }
            }
        }
        assert_eq!(inf, Some(3));
        assert!(out.contains("test_render_seconds_count{k=\"a\"} 3"));
        assert!(out.contains("test_render_seconds_sum{k=\"a\"} 0.000011"));
    }

    #[test]
    fn hist_handles_are_stable_and_per_label() {
        static F: Family = Family::new("test_handles_seconds", "Test family.", &["x"]);
        let a1 = F.hist(&["a"]) as *const Hist;
        let a2 = F.hist(&["a"]) as *const Hist;
        let b = F.hist(&["b"]) as *const Hist;
        assert_eq!(a1, a2);
        assert_ne!(a1, b);
    }
}
