//! `pogo front` — the federated front door daemon.
//!
//! Speaks the existing v2 wire contract to clients and fans out to N
//! backend `pogo serve` daemons:
//!
//! - **control plane** — a [`Registry`] seeded from `--backend`, probed
//!   every `probe_interval` (with the shared transport-retry helper);
//!   `fail_after` consecutive failures turn a node `Down`, which evicts
//!   its pooled connections and triggers re-listing of its queued jobs;
//! - **data plane** — submissions place by rendezvous hashing
//!   ([`super::ring`]) with the id pinned via `X-Pogo-Job-Id`; reads
//!   route by the placement [`Table`] (hash-ring fallback for ids this
//!   replica never saw, so every front replica answers for every job);
//!   the SSE relay forwards event blocks byte-for-bit and reconnects —
//!   deduplicating replayed steps — when a backend drops mid-stream;
//! - **split admission** — global per-tenant quota and cost caps over
//!   the placement table, refreshed lazily before any rejection.
//!
//! The v1 surface is deliberately **not** federated: v2 is the
//! federation surface (it carries the durable series results and the
//! event stream); v1 stays a single-daemon contract.

use super::admission::{FrontAdmission, Refusal};
use super::metrics::FrontMetrics;
use super::proxy::{passthrough, ConnPool};
use super::registry::{NodeState, Probe, Registry};
use super::ring;
use super::table::{Placement, Table};
use crate::serve::client::retry_transport;
use crate::serve::http::{self, ReadError, Request, Response};
use crate::serve::job::JobSpec;
use crate::serve::problem;
use crate::serve::queue::JobId;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Max simultaneous connection-handler threads (same rationale as the
/// backend's cap).
const MAX_CONNS: usize = 64;

/// How long one SSE relay keeps trying (reconnects included) before
/// giving up on a terminal event.
const SSE_RELAY_DEADLINE: Duration = Duration::from_secs(600);

/// Pause between SSE reconnect attempts while a backend is down and its
/// jobs re-list.
const SSE_RECONNECT_PAUSE: Duration = Duration::from_millis(200);

/// Probe attempts per node per tick (rides the shared
/// [`retry_transport`] helper — probes are idempotent GETs).
const PROBE_ATTEMPTS: u32 = 2;

/// Bound on id-collision retries at submit time (each 409 walks the id
/// forward past backend-locally-assigned ids).
const MAX_ID_RETRIES: u32 = 32;

/// Front-door configuration (`pogo front` flags map 1:1).
#[derive(Clone, Debug)]
pub struct FrontConfig {
    /// `HOST:PORT`; port 0 binds an ephemeral port (tests/benches).
    pub addr: String,
    /// Backend `pogo serve` addresses (`--backend a:7070,b:7070`).
    pub backends: Vec<String>,
    pub probe_interval: Duration,
    /// Consecutive probe failures before a backend is `Down`.
    pub fail_after: u32,
    /// Global (cross-shard) admission caps.
    pub admission: FrontAdmission,
    /// Placement-table persistence directory.
    pub state_dir: Option<PathBuf>,
}

impl Default for FrontConfig {
    fn default() -> Self {
        FrontConfig {
            addr: "127.0.0.1:7071".to_string(),
            backends: Vec::new(),
            probe_interval: Duration::from_secs(1),
            fail_after: 2,
            admission: FrontAdmission::default(),
            state_dir: None,
        }
    }
}

struct FrontState {
    cfg: FrontConfig,
    registry: Registry,
    table: Table,
    pool: ConnPool,
    metrics: FrontMetrics,
    next_id: AtomicU64,
    stop: Arc<AtomicBool>,
}

/// A running front door. `shutdown` stops the accept and probe loops.
pub struct Front {
    state: Arc<FrontState>,
    local: SocketAddr,
    accept: Option<std::thread::JoinHandle<()>>,
    probe: Option<std::thread::JoinHandle<()>>,
}

impl Front {
    pub fn start(cfg: FrontConfig) -> Result<Front> {
        anyhow::ensure!(!cfg.backends.is_empty(), "pogo front needs at least one --backend");
        let table = Table::open(cfg.state_dir.as_deref())?;
        let next_id = AtomicU64::new(table.next_id_floor());
        let registry = Registry::new(&cfg.backends, cfg.fail_after);
        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::new(FrontState {
            registry,
            table,
            pool: ConnPool::new(),
            metrics: FrontMetrics::new(),
            next_id,
            stop: stop.clone(),
            cfg,
        });

        let listener = TcpListener::bind(&state.cfg.addr)
            .with_context(|| format!("binding {}", state.cfg.addr))?;
        let local = listener.local_addr()?;

        let st = state.clone();
        let accept = std::thread::Builder::new()
            .name("pogo-front-accept".to_string())
            .spawn(move || {
                let active = Arc::new(AtomicUsize::new(0));
                for conn in listener.incoming() {
                    if st.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            if active.load(Ordering::Relaxed) >= MAX_CONNS {
                                let resp = Response::error(503, "too many connections");
                                http::write_response(&mut stream, &resp).ok();
                                continue;
                            }
                            active.fetch_add(1, Ordering::Relaxed);
                            let st = st.clone();
                            let active = active.clone();
                            let spawned = std::thread::Builder::new()
                                .name("pogo-front-conn".to_string())
                                .spawn(move || {
                                    handle_conn(stream, &st);
                                    active.fetch_sub(1, Ordering::Relaxed);
                                });
                            if let Err(e) = spawned {
                                active.fetch_sub(1, Ordering::Relaxed);
                                log::warn!("failed to spawn front handler: {e}");
                            }
                        }
                        Err(e) => log::warn!("front accept error: {e}"),
                    }
                }
            })
            .context("spawning front accept loop")?;

        let st = state.clone();
        let probe = std::thread::Builder::new()
            .name("pogo-front-probe".to_string())
            .spawn(move || probe_loop(&st))
            .context("spawning front probe loop")?;

        log::info!(
            "pogo front listening on http://{local} over {} backends",
            state.cfg.backends.len()
        );
        Ok(Front { state, local, accept: Some(accept), probe: Some(probe) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.local
    }

    /// Force one probe + re-list pass right now (tests use this instead
    /// of waiting out the probe interval).
    pub fn probe_now(&self) {
        probe_tick(&self.state);
    }

    /// Block until the accept loop exits (the daemon entry point parks
    /// here; absent signal handling a kill stops the process, and a
    /// restart with the same `--state-dir` keeps routing its placements).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
        if let Some(h) = self.probe.take() {
            h.join().ok();
        }
    }

    pub fn shutdown(mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        TcpStream::connect(self.local).ok();
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
        if let Some(h) = self.probe.take() {
            h.join().ok();
        }
    }
}

impl Drop for Front {
    fn drop(&mut self) {
        if !self.state.stop.swap(true, Ordering::SeqCst) {
            TcpStream::connect(self.local).ok();
        }
    }
}

// ---------------------------------------------------------------------
// Control plane: probing + re-listing
// ---------------------------------------------------------------------

fn probe_loop(st: &Arc<FrontState>) {
    while !st.stop.load(Ordering::SeqCst) {
        probe_tick(st);
        // Sleep in short slices so shutdown is prompt.
        let deadline = Instant::now() + st.cfg.probe_interval;
        while Instant::now() < deadline && !st.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

/// One control-plane pass: probe every node, then re-list anything
/// stranded on a `Down` node. Re-listing is level-triggered — it retries
/// every tick until each stranded job lands somewhere — so a transient
/// failure of the *target* node cannot permanently orphan a job.
fn probe_tick(st: &Arc<FrontState>) {
    for node in st.registry.all() {
        let addr = node.addr.clone();
        let probe = match retry_transport(PROBE_ATTEMPTS, || {
            http::request_full(&addr, "GET", "/healthz", None, &[])
        }) {
            Ok((200, _, body)) => match Json::parse(&body) {
                Ok(j) if j.get("status").as_str() == Some("draining") => Probe::Draining,
                Ok(_) => Probe::Healthy,
                Err(e) => Probe::Failed(format!("unparseable healthz: {e}")),
            },
            Ok((status, _, _)) => Probe::Failed(format!("healthz answered HTTP {status}")),
            Err(e) => Probe::Failed(e.to_string()),
        };
        if matches!(probe, Probe::Failed(_)) {
            st.metrics.probe_failures.fetch_add(1, Ordering::Relaxed);
        }
        if st.registry.record(&addr, probe) {
            log::warn!("backend {addr} is down; re-listing its queued jobs");
            st.pool.evict(&addr);
        }
    }
    relist_stranded(st);
}

fn relist_stranded(st: &Arc<FrontState>) {
    let down: Vec<String> = st
        .registry
        .all()
        .into_iter()
        .filter(|n| n.state == NodeState::Down)
        .map(|n| n.addr)
        .collect();
    if down.is_empty() {
        return;
    }
    let placeable = st.registry.placeable();
    for dead in &down {
        for p in st.table.active_on(dead) {
            let id_text = p.id.to_string();
            for cand in ring::candidates(&placeable, p.id) {
                let headers = [
                    ("X-Pogo-Job-Id", id_text.as_str()),
                    ("X-Pogo-Resubmitted", "1"),
                    ("X-Api-Key", p.tenant.as_str()),
                ];
                match st.pool.roundtrip(
                    &cand,
                    "POST",
                    "/v2/jobs",
                    "application/json",
                    p.spec.as_bytes(),
                    &headers,
                ) {
                    // 202 = placed; 409 = a previous (raced) re-list
                    // already landed it here — both mean "it lives there".
                    Ok((202 | 409, _, _)) => {
                        st.table.reassign(p.id, &cand);
                        st.metrics.relists.fetch_add(1, Ordering::Relaxed);
                        log::info!("re-listed job {} from {dead} onto {cand}", p.id);
                        break;
                    }
                    Ok((status, _, body)) => {
                        log::warn!(
                            "re-list of job {} onto {cand}: HTTP {status}: {:.120}",
                            p.id,
                            String::from_utf8_lossy(&body)
                        );
                        continue;
                    }
                    Err(e) => {
                        log::debug!("re-list of job {} onto {cand}: {e}", p.id);
                        continue;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Data plane
// ---------------------------------------------------------------------

enum Routed {
    Plain(Response),
    /// Relay `GET /v2/jobs/:id/events` (needs the socket).
    Events(JobId),
}

fn handle_conn(mut stream: TcpStream, st: &Arc<FrontState>) {
    let req = match http::read_request(&stream) {
        Ok(req) => req,
        Err(e) => {
            if let Some(resp) = e.response() {
                http::write_response(&mut stream, &resp).ok();
            }
            return;
        }
    };
    match route(&req, st) {
        Routed::Plain(resp) => {
            http::write_response(&mut stream, &resp).ok();
        }
        Routed::Events(id) => relay_events(&mut stream, id, st),
    }
}

fn route(req: &Request, st: &Arc<FrontState>) -> Routed {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    let plain = Routed::Plain;
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => {
            let up = st.registry.placeable().len();
            plain(Response::json(
                200,
                &Json::obj(vec![
                    ("status", Json::str(if up > 0 { "ok" } else { "degraded" })),
                    ("role", Json::str("front")),
                    ("version", Json::str(crate::VERSION)),
                    ("backends", Json::num(st.cfg.backends.len() as f64)),
                    ("backends_up", Json::num(up as f64)),
                ]),
            ))
        }
        ("GET", ["metrics"]) => {
            let (tracked, active) = st.table.counts();
            plain(Response::text(
                200,
                st.metrics.render(&st.registry.all(), tracked, active),
            ))
        }
        ("GET", ["front", "nodes"]) => {
            plain(Response::json(200, &st.registry.snapshot_json()))
        }
        ("GET", ["v2", "problems"]) => plain(Response::json(200, &problem::registry_json())),
        ("POST", ["v2", "jobs"]) => plain(submit(req, st)),
        ("GET", ["v2", "jobs"]) => plain(list_jobs(st)),
        ("GET", ["v2", "jobs", id]) => plain(match parse_id(id) {
            Some(id) => proxy_job_read(id, "", st),
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("GET", ["v2", "jobs", id, "result"]) => plain(match parse_id(id) {
            Some(id) => proxy_job_read(id, "/result", st),
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("GET", ["v2", "jobs", id, "trace"]) => plain(match parse_id(id) {
            Some(id) => proxy_job_read(id, "/trace", st),
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("GET", ["v2", "jobs", id, "events"]) => match parse_id(id) {
            Some(id) => Routed::Events(id),
            None => plain(Response::error(400, format!("bad job id '{id}'"))),
        },
        ("DELETE", ["v2", "jobs", id]) => plain(match parse_id(id) {
            Some(id) => cancel_job(id, st),
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("POST", ["v2", "artifacts"]) => plain(upload_artifact(req, st)),
        ("GET", ["v2", "artifacts"]) => plain(proxy_any("GET", "/v2/artifacts", st)),
        ("GET", ["v2", "artifacts", hash]) => {
            plain(proxy_any("GET", &format!("/v2/artifacts/{hash}"), st))
        }
        (_, ["v1", ..]) => plain(Response::error(
            404,
            "the front door federates the v2 surface only — talk v1 to a backend directly",
        )),
        _ => plain(Response::error(
            404,
            format!("no front route for {} {}", req.method, req.path),
        )),
    }
}

fn parse_id(s: &str) -> Option<JobId> {
    s.parse::<JobId>().ok()
}

/// The node a job routes to: its placement if this front (or its state
/// file) saw the submission, else the rendezvous owner among readable
/// nodes — the deterministic fallback that lets any front replica answer
/// for any job.
fn route_node(id: JobId, st: &FrontState) -> Option<(String, bool)> {
    if let Some(p) = st.table.get(id) {
        // A placement naming a node that is no longer configured (the
        // fleet was re-addressed between front restarts) routes like an
        // unknown id: by the ring, onto the current node set.
        if st.registry.state_of(&p.node).is_some() {
            return Some((p.node, p.resubmitted));
        }
        let readable = st.registry.readable();
        return ring::owner(&readable, id).map(|n| (n.to_string(), p.resubmitted));
    }
    let readable = st.registry.readable();
    ring::owner(&readable, id).map(|n| (n.to_string(), false))
}

fn submit(req: &Request, st: &Arc<FrontState>) -> Response {
    let body = match req.body_utf8() {
        Ok(b) => b.to_string(),
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    let parsed = match Json::parse(&body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, format!("bad JSON: {e}")),
    };
    let spec = match JobSpec::from_json(&parsed) {
        Ok(s) => s,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    let tenant = tenant_of(req);
    let cost = spec.cost();

    // Global admission: on a would-reject, refresh the ledger from the
    // backends first — never 429 off stale bookkeeping.
    if st.cfg.admission.check(&st.table, &tenant, cost).is_err() {
        refresh_ledger(st, &tenant);
    }
    if let Err(refusal) = st.cfg.admission.check(&st.table, &tenant, cost) {
        let counter = match &refusal {
            Refusal::Quota { .. } => &st.metrics.rejected_quota,
            Refusal::Cost { .. } => &st.metrics.rejected_cost,
        };
        counter.fetch_add(1, Ordering::Relaxed);
        let retry = st
            .cfg
            .admission
            .retry_after_s(&st.table, st.registry.placeable().len());
        return Response::error(429, refusal.to_string())
            .with_header("Retry-After", retry.to_string());
    }

    let placeable = st.registry.placeable();
    if placeable.is_empty() {
        return Response::error(503, "no backends are up").with_header("Retry-After", "1");
    }

    // Allocate an id, place on the ring, forward. A 409 means that id is
    // taken on the target backend (e.g. direct-to-backend submissions);
    // walk the id forward — with exponentially growing strides, so a
    // backend whose local counter ran far ahead is caught in a few
    // round-trips — and re-place.
    for attempt in 0..MAX_ID_RETRIES {
        let id = st.next_id.fetch_add(1 << attempt.min(16), Ordering::SeqCst);
        let id_text = id.to_string();
        let mut last_transport: Option<ReadError> = None;
        let mut took_id = false;
        for cand in ring::candidates(&placeable, id) {
            let headers =
                [("X-Pogo-Job-Id", id_text.as_str()), ("X-Api-Key", tenant.as_str())];
            match st.pool.roundtrip(
                &cand,
                "POST",
                "/v2/jobs",
                "application/json",
                body.as_bytes(),
                &headers,
            ) {
                Ok((202, resp_headers, resp_body)) => {
                    st.table.insert(Placement {
                        id,
                        node: cand.clone(),
                        tenant: tenant.clone(),
                        cost,
                        spec: body,
                        resubmitted: false,
                        terminal: false,
                    });
                    st.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                    return passthrough(
                        202,
                        &resp_headers,
                        resp_body,
                        &[("X-Pogo-Backend", cand)],
                    );
                }
                Ok((409, _, _)) => {
                    took_id = true;
                    break; // new id, try again
                }
                // Backend-local refusal (429/503/400/413/404): the
                // contract answer, passed through verbatim.
                Ok((status, resp_headers, resp_body)) => {
                    return passthrough(status, &resp_headers, resp_body, &[]);
                }
                Err(e) => {
                    last_transport = Some(e);
                    continue; // next ring candidate
                }
            }
        }
        if !took_id {
            return Response::error(
                503,
                format!(
                    "no backend reachable for placement: {}",
                    last_transport.map(|e| e.to_string()).unwrap_or_default()
                ),
            )
            .with_header("Retry-After", "1");
        }
    }
    Response::error(503, "could not allocate an unclaimed job id")
}

fn list_jobs(st: &Arc<FrontState>) -> Response {
    let mut rows: Vec<Json> = Vec::new();
    for node in st.registry.readable() {
        st.metrics.proxied.fetch_add(1, Ordering::Relaxed);
        if let Ok((200, _, body)) =
            st.pool.roundtrip(&node, "GET", "/v2/jobs", "application/json", b"", &[])
        {
            if let Ok(Json::Arr(list)) = Json::parse(&String::from_utf8_lossy(&body)) {
                rows.extend(list);
            }
        }
    }
    rows.sort_by_key(|j| j.get("id").as_usize().unwrap_or(usize::MAX));
    Response::json(200, &Json::arr(rows))
}

fn proxy_job_read(id: JobId, suffix: &str, st: &Arc<FrontState>) -> Response {
    let Some((node, resubmitted)) = route_node(id, st) else {
        return Response::error(503, "no backends are up");
    };
    st.metrics.proxied.fetch_add(1, Ordering::Relaxed);
    let path = format!("/v2/jobs/{id}{suffix}");
    match retry_transport(2, || {
        st.pool.roundtrip(&node, "GET", &path, "application/json", b"", &[])
    }) {
        Ok((status, headers, body)) => {
            // Keep the ledger fresh for free on status/result reads.
            if status == 200 && (suffix.is_empty() || suffix == "/result") {
                if let Ok(j) = Json::parse(&String::from_utf8_lossy(&body)) {
                    if matches!(
                        j.get("state").as_str(),
                        Some("done" | "failed" | "cancelled")
                    ) {
                        st.table.mark_terminal(id);
                    }
                }
            }
            let extra: Vec<(&'static str, String)> = if resubmitted {
                vec![("X-Pogo-Resubmitted", "1".to_string())]
            } else {
                Vec::new()
            };
            passthrough(status, &headers, body, &extra)
        }
        Err(e) => Response::error(503, format!("backend {node} unreachable: {e}")),
    }
}

fn cancel_job(id: JobId, st: &Arc<FrontState>) -> Response {
    let Some((node, _)) = route_node(id, st) else {
        return Response::error(503, "no backends are up");
    };
    st.metrics.proxied.fetch_add(1, Ordering::Relaxed);
    let path = format!("/v2/jobs/{id}");
    match st.pool.roundtrip(&node, "DELETE", &path, "application/json", b"", &[]) {
        Ok((status, headers, body)) => {
            if status == 200 {
                st.table.mark_terminal(id);
            }
            passthrough(status, &headers, body, &[])
        }
        Err(e) => Response::error(503, format!("backend {node} unreachable: {e}")),
    }
}

/// Artifact upload fan-out: replicate the (content-addressed, idempotent)
/// artifact to every placeable backend so any ring placement can run
/// jobs that reference it. `201`/`409` both count as stored.
fn upload_artifact(req: &Request, st: &Arc<FrontState>) -> Response {
    let nodes = st.registry.placeable();
    if nodes.is_empty() {
        return Response::error(503, "no backends are up");
    }
    let mut stored: Option<(u16, Vec<(String, String)>, Vec<u8>)> = None;
    let mut failure: Option<Response> = None;
    let mut replicas = 0usize;
    for node in &nodes {
        st.metrics.proxied.fetch_add(1, Ordering::Relaxed);
        match st.pool.roundtrip(
            node,
            "POST",
            "/v2/artifacts",
            "application/octet-stream",
            &req.body,
            &[],
        ) {
            Ok((status @ (201 | 409), headers, body)) => {
                replicas += 1;
                // Prefer reporting the first fresh store over a 409.
                if stored.is_none() || status == 201 {
                    stored = Some((status, headers, body));
                }
            }
            Ok((status, headers, body)) => {
                failure = Some(passthrough(status, &headers, body, &[]));
            }
            Err(e) => {
                failure =
                    Some(Response::error(503, format!("backend {node} unreachable: {e}")));
            }
        }
    }
    match stored {
        Some((status, headers, body)) => passthrough(
            status,
            &headers,
            body,
            &[("X-Pogo-Replicas", replicas.to_string())],
        ),
        // Nothing accepted it: surface the last backend answer.
        None => failure.unwrap_or_else(|| Response::error(503, "no backends are up")),
    }
}

/// Proxy a read to the first readable backend that answers.
fn proxy_any(method: &str, path: &str, st: &Arc<FrontState>) -> Response {
    let nodes = st.registry.readable();
    for node in &nodes {
        st.metrics.proxied.fetch_add(1, Ordering::Relaxed);
        match st.pool.roundtrip(node, method, path, "application/json", b"", &[]) {
            Ok((status, headers, body)) => return passthrough(status, &headers, body, &[]),
            Err(_) => continue,
        }
    }
    Response::error(503, "no backends are up")
}

/// The tenant identity (same rule as the backend's `tenant_of`, so the
/// front and its shards account under identical keys).
fn tenant_of(req: &Request) -> String {
    let raw = req.header("x-api-key").unwrap_or("").trim();
    if raw.is_empty() {
        "anonymous".to_string()
    } else {
        raw.chars().take(64).collect()
    }
}

/// Refresh the admission ledger from the backends: every active
/// placement (for `tenant`, plus everything when a cost cap is set) gets
/// one status read; terminal — or vanished — jobs stop counting. Called
/// only when a rejection is on the line, so the steady-state submit path
/// costs no extra round-trips.
fn refresh_ledger(st: &Arc<FrontState>, tenant: &str) {
    let mut targets = st.table.active_for(tenant);
    if st.cfg.admission.cost_cap > 0 {
        for node in st.registry.readable() {
            for p in st.table.active_on(&node) {
                if p.tenant != tenant {
                    targets.push(p);
                }
            }
        }
    }
    for p in targets {
        match st.pool.roundtrip(
            &p.node,
            "GET",
            &format!("/v2/jobs/{}", p.id),
            "application/json",
            b"",
            &[],
        ) {
            Ok((200, _, body)) => {
                if let Ok(j) = Json::parse(&String::from_utf8_lossy(&body)) {
                    if matches!(
                        j.get("state").as_str(),
                        Some("done" | "failed" | "cancelled")
                    ) {
                        st.table.mark_terminal(p.id);
                    }
                }
            }
            // The backend no longer knows the job (restarted without
            // state): it must not pin quota forever.
            Ok((404, _, _)) => st.table.mark_terminal(p.id),
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------
// SSE relay
// ---------------------------------------------------------------------

/// Relay one job's event stream, reconnecting across backend drops.
///
/// Blocks are forwarded byte-for-bit ([`http::relay_sse_blocks`]
/// preserves boundaries); after a reconnect, progress events whose step
/// is ≤ the last forwarded one are dropped — the backend replays its
/// buffered tail to late subscribers, and after a re-list the surviving
/// node re-runs the job from step 1.
fn relay_events(stream: &mut TcpStream, id: JobId, st: &Arc<FrontState>) {
    // Unknown ids answer a clean 404 *before* the stream head goes out.
    let Some((first_node, resubmitted)) = route_node(id, st) else {
        http::write_response(stream, &Response::error(503, "no backends are up")).ok();
        return;
    };
    {
        let probe = st.pool.roundtrip(
            &first_node,
            "GET",
            &format!("/v2/jobs/{id}"),
            "application/json",
            b"",
            &[],
        );
        if let Ok((404, headers, body)) = probe {
            http::write_response(stream, &passthrough(404, &headers, body, &[])).ok();
            return;
        }
    }
    let id_text = id.to_string();
    let mut head = vec![("X-Job-Id", id_text.as_str())];
    if resubmitted {
        head.push(("X-Pogo-Resubmitted", "1"));
    }
    if http::write_stream_head(stream, 200, "text/event-stream", &head).is_err() {
        return;
    }

    let deadline = Instant::now() + SSE_RELAY_DEADLINE;
    let mut last_step: Option<usize> = None;
    let mut finished = false;
    let mut first_attempt = true;
    while !finished && Instant::now() < deadline && !st.stop.load(Ordering::SeqCst) {
        if !first_attempt {
            st.metrics.sse_reconnects.fetch_add(1, Ordering::Relaxed);
            // Keep the client's read timeout alive while the backend
            // recovers / the job re-lists.
            if http::write_chunk(stream, b": reconnecting\n\n").is_err() {
                return;
            }
            std::thread::sleep(SSE_RECONNECT_PAUSE);
        }
        first_attempt = false;
        let Some((node, _)) = route_node(id, st) else {
            continue;
        };
        let path = format!("/v2/jobs/{id}/events");
        let remaining = deadline.saturating_duration_since(Instant::now());
        let mut client_gone = false;
        let result = http::relay_sse_blocks(&node, &path, &[], remaining, &mut |block| {
            match classify_block(block) {
                Block::Progress(step) => {
                    if last_step.is_some_and(|last| step <= last) {
                        return true; // replayed after reconnect: drop
                    }
                    last_step = Some(step);
                }
                Block::Terminal => finished = true,
                Block::Other => {}
            }
            if http::write_chunk(stream, block).is_err() {
                client_gone = true;
                return false;
            }
            !finished
        });
        if client_gone {
            return;
        }
        match result {
            // Clean end: terminal seen, or the backend finished the
            // stream (it only does so after its terminal event).
            Ok(()) => finished = true,
            Err(ReadError::Transport(e)) => {
                log::debug!("SSE relay for job {id} lost {node}: {e}; reconnecting");
            }
            Err(ReadError::Protocol { status, .. }) => {
                // The job is (momentarily) unknown there — e.g. mid
                // re-list. Retry until the deadline.
                log::debug!("SSE relay for job {id}: {node} answered {status}; retrying");
            }
        }
    }
    http::finish_chunked(stream).ok();
}

enum Block {
    Progress(usize),
    Terminal,
    Other,
}

/// Classify one raw SSE block (comment blocks and anything unparseable
/// are `Other` — forwarded, never deduplicated).
fn classify_block(block: &[u8]) -> Block {
    let text = String::from_utf8_lossy(block);
    let mut event = "";
    let mut data = "";
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("event:") {
            event = rest.trim();
        } else if let Some(rest) = line.strip_prefix("data:") {
            data = rest.trim();
        }
    }
    match event {
        "progress" => match Json::parse(data).ok().and_then(|j| j.get("step").as_usize()) {
            Some(step) => Block::Progress(step),
            None => Block::Other,
        },
        "state" => Block::Terminal,
        _ => Block::Other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_recognizes_the_wire_blocks() {
        assert!(matches!(
            classify_block(b"event: progress\ndata: {\"step\":7,\"loss\":0.5}\n\n"),
            Block::Progress(7)
        ));
        assert!(matches!(
            classify_block(b"event: state\ndata: {\"id\":1,\"state\":\"done\"}\n\n"),
            Block::Terminal
        ));
        assert!(matches!(classify_block(b": keepalive\n\n"), Block::Other));
        assert!(matches!(classify_block(b"event: progress\ndata: junk\n\n"), Block::Other));
    }
}
