//! The data plane's connection pool: one warm keep-alive socket per
//! backend instead of a TCP connect per proxied request.
//!
//! Built on [`http::Conn`]. Connections check out of the pool for one
//! round-trip and return on success; any transport error poisons the
//! connection (it is simply dropped). A request that fails on a *reused*
//! connection retries once on a fresh one — the backend may have
//! legitimately hung up between requests (idle timeout, its per-conn
//! request cap), and a request written into a closing socket was never
//! processed.

use crate::serve::http::{self, Conn, ReadError};
use std::collections::HashMap;
use std::sync::Mutex;

/// Pooled idle connections per backend (beyond this, extras just close).
const POOL_PER_BACKEND: usize = 8;

#[derive(Default)]
pub struct ConnPool {
    idle: Mutex<HashMap<String, Vec<Conn>>>,
}

impl ConnPool {
    pub fn new() -> ConnPool {
        ConnPool::default()
    }

    /// One proxied round-trip to `addr`; returns (status, headers, body).
    pub fn roundtrip(
        &self,
        addr: &str,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
        headers: &[(&str, &str)],
    ) -> Result<(u16, Vec<(String, String)>, Vec<u8>), ReadError> {
        let pooled = self.take(addr);
        let reused = pooled.is_some();
        let mut conn = match pooled {
            Some(c) => c,
            None => Conn::connect(addr)?,
        };
        match conn.roundtrip(method, path, content_type, body, headers) {
            Ok(resp) => {
                self.put(addr, conn);
                Ok(resp)
            }
            Err(ReadError::Transport(_)) if reused => {
                // Stale pooled socket; one fresh attempt.
                let mut fresh = Conn::connect(addr)?;
                let resp = fresh.roundtrip(method, path, content_type, body, headers)?;
                self.put(addr, fresh);
                Ok(resp)
            }
            Err(e) => Err(e),
        }
    }

    /// Drop every pooled connection to `addr` (the node went down).
    pub fn evict(&self, addr: &str) {
        self.idle.lock().unwrap().remove(addr);
    }

    fn take(&self, addr: &str) -> Option<Conn> {
        self.idle.lock().unwrap().get_mut(addr)?.pop()
    }

    fn put(&self, addr: &str, conn: Conn) {
        let mut idle = self.idle.lock().unwrap();
        let pool = idle.entry(addr.to_string()).or_default();
        if pool.len() < POOL_PER_BACKEND {
            pool.push(conn);
        }
    }
}

/// Forward a backend response to the front's client as-is: status, the
/// relay-relevant headers, and the body verbatim. Hop-scoped headers
/// (`Connection`, lengths) are re-derived by the writer.
pub fn passthrough(
    status: u16,
    headers: &[(String, String)],
    body: Vec<u8>,
    extra: &[(&'static str, String)],
) -> http::Response {
    let mut resp = http::Response {
        status,
        content_type: "application/json",
        headers: Vec::new(),
        body,
    };
    for (k, v) in headers {
        // Forward the API-meaningful headers only; framing is re-done
        // per hop. The static-name table keeps Response's `&'static str`
        // header keys (and bounds what a backend can inject).
        for known in ["Retry-After", "X-Quota-Remaining", "X-Cost-Remaining", "X-Job-Id"] {
            if k.eq_ignore_ascii_case(known) {
                resp = resp.with_header(known, v.clone());
            }
        }
    }
    for (k, v) in extra {
        resp = resp.with_header(k, v.clone());
    }
    resp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::http::{read_request, wants_keep_alive, write_response_conn, Response};
    use crate::util::json::Json;
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn spawn_keepalive_echo() -> (String, Arc<AtomicUsize>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let conns = Arc::new(AtomicUsize::new(0));
        let counter = conns.clone();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { break };
                counter.fetch_add(1, Ordering::SeqCst);
                loop {
                    let Ok(req) = read_request(&stream) else { break };
                    let keep = wants_keep_alive(&req);
                    let resp = Response::json(
                        200,
                        &Json::obj(vec![("path", Json::str(req.path.clone()))]),
                    );
                    if write_response_conn(&mut stream, &resp, keep).is_err() || !keep {
                        break;
                    }
                }
            }
        });
        (addr, conns)
    }

    #[test]
    fn pool_reuses_one_connection_per_backend() {
        let (addr, conns) = spawn_keepalive_echo();
        let pool = ConnPool::new();
        for i in 0..6 {
            let (status, _, body) = pool
                .roundtrip(&addr, "GET", &format!("/v2/jobs/{i}"), "application/json", b"", &[])
                .unwrap();
            assert_eq!(status, 200);
            assert!(String::from_utf8(body).unwrap().contains(&format!("/v2/jobs/{i}")));
        }
        assert_eq!(conns.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn passthrough_keeps_api_headers_only() {
        let headers = vec![
            ("Retry-After".to_string(), "7".to_string()),
            ("Connection".to_string(), "keep-alive".to_string()),
            ("X-Evil".to_string(), "1".to_string()),
        ];
        let resp = passthrough(429, &headers, b"{}".to_vec(), &[("X-Pogo-Resubmitted", "1".to_string())]);
        assert_eq!(resp.status, 429);
        assert!(resp.headers.iter().any(|(k, v)| *k == "Retry-After" && v == "7"));
        assert!(resp.headers.iter().any(|(k, v)| *k == "X-Pogo-Resubmitted" && v == "1"));
        assert!(!resp.headers.iter().any(|(k, _)| *k == "Connection" || *k == "X-Evil"));
    }
}
