//! Split admission: the global half of quota enforcement.
//!
//! Each backend still enforces its *local* `Admission` caps; the front
//! door enforces per-tenant quotas and the cost budget **across all
//! shards**, using its placement [`Table`](super::table::Table) as the
//! ledger. Without this, a tenant with quota N could hold N jobs on
//! every backend. The decision is pure bookkeeping here; the front
//! refreshes stale ledger entries (lazily, only when a rejection is on
//! the line) before trusting a reject.

use super::table::Table;
use crate::serve::queue;

/// Global caps, mirroring the per-backend `serve::queue::Admission`
/// semantics: `0` = unlimited.
#[derive(Clone, Copy, Debug, Default)]
pub struct FrontAdmission {
    /// Max active (queued + running) jobs per tenant, summed across all
    /// backends.
    pub tenant_quota: usize,
    /// Max outstanding `B·p·n·steps` cost units across all backends.
    pub cost_cap: u64,
}

/// Why the front door refused a submission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Refusal {
    Quota { tenant: String, active: usize, quota: usize },
    Cost { outstanding: u64, job: u64, cap: u64 },
}

impl std::fmt::Display for Refusal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Refusal::Quota { tenant, active, quota } => write!(
                f,
                "tenant '{tenant}' has {active} active jobs across the federation \
                 (global quota {quota})"
            ),
            Refusal::Cost { outstanding, job, cap } => write!(
                f,
                "job cost {job} would push the federation's outstanding cost past \
                 {cap} (currently {outstanding})"
            ),
        }
    }
}

impl FrontAdmission {
    /// Check `tenant`'s submission of a job costing `cost` against the
    /// ledger. Callers should refresh the table first when this rejects
    /// — a stale active entry must not 429 a live client.
    pub fn check(&self, table: &Table, tenant: &str, cost: u64) -> Result<(), Refusal> {
        if self.tenant_quota > 0 {
            let active = table.active_for(tenant).len();
            if active >= self.tenant_quota {
                return Err(Refusal::Quota {
                    tenant: tenant.to_string(),
                    active,
                    quota: self.tenant_quota,
                });
            }
        }
        if self.cost_cap > 0 {
            let outstanding = table.outstanding_cost();
            if outstanding.saturating_add(cost) > self.cost_cap {
                return Err(Refusal::Cost { outstanding, job: cost, cap: self.cost_cap });
            }
        }
        Ok(())
    }

    /// The `Retry-After` seconds for a refusal — the same
    /// histogram-derived estimate the backends use (falling back to the
    /// pending-count heuristic until this process has observed jobs).
    pub fn retry_after_s(&self, table: &Table, workers_up: usize) -> u64 {
        let (_, active) = table.counts();
        queue::retry_after_hint(active, workers_up.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::federate::table::Placement;

    fn seed(table: &Table, id: u64, tenant: &str, cost: u64) {
        table.insert(Placement {
            id,
            node: "a:1".to_string(),
            tenant: tenant.to_string(),
            cost,
            spec: String::new(),
            resubmitted: false,
            terminal: false,
        });
    }

    #[test]
    fn quota_counts_across_every_node() {
        let table = Table::open(None).unwrap();
        seed(&table, 1, "alice", 10);
        seed(&table, 2, "alice", 10);
        // Spread over two nodes: still two active jobs for alice.
        table.reassign(2, "b:2");
        let adm = FrontAdmission { tenant_quota: 2, cost_cap: 0 };
        assert!(matches!(
            adm.check(&table, "alice", 10),
            Err(Refusal::Quota { active: 2, quota: 2, .. })
        ));
        assert!(adm.check(&table, "bob", 10).is_ok());
        // A terminal job frees the slot.
        table.mark_terminal(1);
        assert!(adm.check(&table, "alice", 10).is_ok());
    }

    #[test]
    fn cost_cap_is_federation_wide() {
        let table = Table::open(None).unwrap();
        seed(&table, 1, "alice", 600);
        seed(&table, 2, "bob", 300);
        let adm = FrontAdmission { tenant_quota: 0, cost_cap: 1000 };
        assert!(adm.check(&table, "carol", 100).is_ok());
        assert_eq!(
            adm.check(&table, "carol", 200),
            Err(Refusal::Cost { outstanding: 900, job: 200, cap: 1000 })
        );
    }

    #[test]
    fn zero_caps_admit_everything() {
        let table = Table::open(None).unwrap();
        for i in 0..50 {
            seed(&table, i, "alice", u64::MAX / 64);
        }
        let adm = FrontAdmission::default();
        assert!(adm.check(&table, "alice", u64::MAX).is_ok());
    }
}
