//! The front door's control plane: which backends exist and whether
//! they are taking traffic.
//!
//! Nodes are seeded from `--backend` and probed periodically (the probe
//! loop lives in [`super::front`]; this module is the pure state
//! machine). A node is `Up` until `fail_after` consecutive probe
//! failures mark it `Down`; a backend whose `/healthz` reports
//! `"draining"` turns `Draining` — it keeps serving reads and its
//! in-flight jobs, but placement skips it. One successful probe brings
//! any node straight back to `Up`: the job table, not the registry,
//! remembers what was re-listed away in the meantime.

use crate::util::json::Json;
use std::sync::Mutex;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    Up,
    Draining,
    Down,
}

impl NodeState {
    pub fn name(self) -> &'static str {
        match self {
            NodeState::Up => "up",
            NodeState::Draining => "draining",
            NodeState::Down => "down",
        }
    }
}

/// One backend as the registry sees it.
#[derive(Clone, Debug)]
pub struct Node {
    pub addr: String,
    pub state: NodeState,
    /// Consecutive failed probes (reset by any success).
    pub failures: u32,
    /// The last probe error, for `/front/nodes` diagnostics.
    pub last_error: Option<String>,
}

/// What one probe observed about a backend.
#[derive(Clone, Debug)]
pub enum Probe {
    /// `/healthz` answered `"ok"`.
    Healthy,
    /// `/healthz` answered `"draining"`.
    Draining,
    /// The probe failed (transport or a non-200).
    Failed(String),
}

pub struct Registry {
    nodes: Mutex<Vec<Node>>,
    /// Consecutive failures before a node is declared `Down`.
    fail_after: u32,
}

impl Registry {
    pub fn new(addrs: &[String], fail_after: u32) -> Registry {
        let nodes = addrs
            .iter()
            .map(|a| Node {
                addr: a.clone(),
                state: NodeState::Up,
                failures: 0,
                last_error: None,
            })
            .collect();
        Registry { nodes: Mutex::new(nodes), fail_after: fail_after.max(1) }
    }

    /// Addresses eligible for new placements (state `Up`).
    pub fn placeable(&self) -> Vec<String> {
        self.nodes
            .lock()
            .unwrap()
            .iter()
            .filter(|n| n.state == NodeState::Up)
            .map(|n| n.addr.clone())
            .collect()
    }

    /// Addresses still answering reads (`Up` or `Draining`).
    pub fn readable(&self) -> Vec<String> {
        self.nodes
            .lock()
            .unwrap()
            .iter()
            .filter(|n| n.state != NodeState::Down)
            .map(|n| n.addr.clone())
            .collect()
    }

    pub fn all(&self) -> Vec<Node> {
        self.nodes.lock().unwrap().clone()
    }

    pub fn state_of(&self, addr: &str) -> Option<NodeState> {
        self.nodes.lock().unwrap().iter().find(|n| n.addr == addr).map(|n| n.state)
    }

    /// Fold one probe observation in. Returns `true` when this probe
    /// *transitioned* the node to `Down` — the edge the front door
    /// re-lists on (level-triggered retries happen elsewhere).
    pub fn record(&self, addr: &str, probe: Probe) -> bool {
        let mut nodes = self.nodes.lock().unwrap();
        let Some(node) = nodes.iter_mut().find(|n| n.addr == addr) else {
            return false;
        };
        match probe {
            Probe::Healthy => {
                node.failures = 0;
                node.last_error = None;
                node.state = NodeState::Up;
                false
            }
            Probe::Draining => {
                node.failures = 0;
                node.last_error = None;
                node.state = NodeState::Draining;
                false
            }
            Probe::Failed(err) => {
                node.failures = node.failures.saturating_add(1);
                node.last_error = Some(err);
                if node.failures >= self.fail_after && node.state != NodeState::Down {
                    node.state = NodeState::Down;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// The `GET /front/nodes` body.
    pub fn snapshot_json(&self) -> Json {
        Json::arr(self.nodes.lock().unwrap().iter().map(|n| {
            Json::obj(vec![
                ("addr", Json::str(n.addr.clone())),
                ("state", Json::str(n.state.name())),
                ("failures", Json::num(n.failures as f64)),
                (
                    "last_error",
                    match &n.last_error {
                        Some(e) => Json::str(e.clone()),
                        None => Json::Null,
                    },
                ),
            ])
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Registry {
        Registry::new(&["a:1".to_string(), "b:2".to_string()], 2)
    }

    #[test]
    fn down_after_consecutive_failures_only() {
        let r = registry();
        assert!(!r.record("a:1", Probe::Failed("boom".into())));
        assert_eq!(r.state_of("a:1"), Some(NodeState::Up));
        // A success in between resets the streak.
        assert!(!r.record("a:1", Probe::Healthy));
        assert!(!r.record("a:1", Probe::Failed("boom".into())));
        assert_eq!(r.state_of("a:1"), Some(NodeState::Up));
        // Two in a row: the transition fires exactly once.
        assert!(r.record("a:1", Probe::Failed("boom".into())));
        assert_eq!(r.state_of("a:1"), Some(NodeState::Down));
        assert!(!r.record("a:1", Probe::Failed("still down".into())));
        assert_eq!(r.placeable(), vec!["b:2".to_string()]);
    }

    #[test]
    fn draining_blocks_placement_but_not_reads() {
        let r = registry();
        r.record("b:2", Probe::Draining);
        assert_eq!(r.placeable(), vec!["a:1".to_string()]);
        assert_eq!(r.readable().len(), 2);
        // Recovery goes straight back to Up.
        r.record("b:2", Probe::Healthy);
        assert_eq!(r.placeable().len(), 2);
    }

    #[test]
    fn snapshot_carries_state_and_last_error() {
        let r = registry();
        r.record("a:1", Probe::Failed("connection refused".into()));
        let snap = r.snapshot_json();
        let rows = snap.as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("state").as_str(), Some("up"));
        assert_eq!(rows[0].get("last_error").as_str(), Some("connection refused"));
        assert_eq!(rows[1].get("last_error"), &Json::Null);
    }
}
