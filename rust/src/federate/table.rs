//! The front door's replicated job state: which backend owns which job,
//! under which tenant, at what admission cost.
//!
//! The table is the front's authoritative routing and accounting record:
//! reads route by it (with the hash ring as fallback for ids it has
//! never seen), global admission counts active placements in it, and the
//! re-list path walks it when a backend goes down. With `--state-dir` it
//! persists as `front-jobs.json` (write-then-rename, same discipline as
//! the queue's `jobs.json`) so a restarted front keeps routing the jobs
//! it placed before.

use crate::serve::queue::JobId;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One job the front has placed (or inherited from its state file).
#[derive(Clone, Debug)]
pub struct Placement {
    pub id: JobId,
    /// The backend currently owning the job.
    pub node: String,
    pub tenant: String,
    /// Admission cost units (`B·p·n·steps`) counted against the global cap.
    pub cost: u64,
    /// The submitted spec, verbatim JSON — what a re-list re-posts.
    pub spec: String,
    /// Set once the job has been re-listed onto a different node; reads
    /// answer with `X-Pogo-Resubmitted: 1` so clients can tell.
    pub resubmitted: bool,
    /// Terminal placements stop counting against quotas/cost but stay
    /// routable (results live on the backend, spilled to its state dir).
    pub terminal: bool,
}

pub struct Table {
    path: Option<PathBuf>,
    inner: Mutex<BTreeMap<JobId, Placement>>,
}

impl Table {
    /// An empty table, persisted under `state_dir` when given (loading
    /// whatever a previous front left there).
    pub fn open(state_dir: Option<&Path>) -> Result<Table> {
        let path = state_dir.map(|d| d.join("front-jobs.json"));
        let mut jobs = BTreeMap::new();
        if let Some(p) = &path {
            if p.exists() {
                let text = std::fs::read_to_string(p)
                    .with_context(|| format!("reading {}", p.display()))?;
                for row in Json::parse(&text)
                    .with_context(|| format!("parsing {}", p.display()))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("{} is not a JSON array", p.display()))?
                {
                    let placement = Placement {
                        id: row.get("id").as_usize().ok_or_else(|| anyhow!("row without id"))?
                            as JobId,
                        node: row
                            .get("node")
                            .as_str()
                            .ok_or_else(|| anyhow!("row without node"))?
                            .to_string(),
                        tenant: row.get("tenant").as_str().unwrap_or("anonymous").to_string(),
                        cost: row.get("cost").as_f64().unwrap_or(0.0) as u64,
                        spec: row.get("spec").as_str().unwrap_or("").to_string(),
                        resubmitted: row.get("resubmitted").as_bool().unwrap_or(false),
                        terminal: row.get("terminal").as_bool().unwrap_or(false),
                    };
                    jobs.insert(placement.id, placement);
                }
            }
        }
        Ok(Table { path, inner: Mutex::new(jobs) })
    }

    /// The first id a fresh front should hand out: one past anything it
    /// has ever placed (backend-side `X-Pogo-Job-Id` collisions with
    /// directly-submitted jobs still answer 409 and bump further).
    pub fn next_id_floor(&self) -> JobId {
        self.inner.lock().unwrap().keys().next_back().map(|&id| id + 1).unwrap_or(1)
    }

    pub fn insert(&self, p: Placement) {
        self.inner.lock().unwrap().insert(p.id, p);
        self.persist();
    }

    pub fn get(&self, id: JobId) -> Option<Placement> {
        self.inner.lock().unwrap().get(&id).cloned()
    }

    /// Move a job to a new node (a successful re-list).
    pub fn reassign(&self, id: JobId, node: &str) {
        if let Some(p) = self.inner.lock().unwrap().get_mut(&id) {
            p.node = node.to_string();
            p.resubmitted = true;
        }
        self.persist();
    }

    pub fn mark_terminal(&self, id: JobId) {
        let changed = {
            let mut jobs = self.inner.lock().unwrap();
            match jobs.get_mut(&id) {
                Some(p) if !p.terminal => {
                    p.terminal = true;
                    true
                }
                _ => false,
            }
        };
        if changed {
            self.persist();
        }
    }

    /// Non-terminal placements currently routed to `node` — what a
    /// `Down` transition re-lists.
    pub fn active_on(&self, node: &str) -> Vec<Placement> {
        self.inner
            .lock()
            .unwrap()
            .values()
            .filter(|p| !p.terminal && p.node == node)
            .cloned()
            .collect()
    }

    /// Non-terminal placements for one tenant (global quota accounting).
    pub fn active_for(&self, tenant: &str) -> Vec<Placement> {
        self.inner
            .lock()
            .unwrap()
            .values()
            .filter(|p| !p.terminal && p.tenant == tenant)
            .cloned()
            .collect()
    }

    /// Total non-terminal admission cost across every tenant and shard.
    pub fn outstanding_cost(&self) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .values()
            .filter(|p| !p.terminal)
            .map(|p| p.cost)
            .fold(0u64, u64::saturating_add)
    }

    /// (tracked, active) counts for `/metrics`.
    pub fn counts(&self) -> (usize, usize) {
        let jobs = self.inner.lock().unwrap();
        let active = jobs.values().filter(|p| !p.terminal).count();
        (jobs.len(), active)
    }

    fn persist(&self) {
        let Some(path) = &self.path else { return };
        let rows: Vec<Json> = self
            .inner
            .lock()
            .unwrap()
            .values()
            .map(|p| {
                Json::obj(vec![
                    ("id", Json::num(p.id as f64)),
                    ("node", Json::str(p.node.clone())),
                    ("tenant", Json::str(p.tenant.clone())),
                    ("cost", Json::num(p.cost as f64)),
                    ("spec", Json::str(p.spec.clone())),
                    ("resubmitted", Json::Bool(p.resubmitted)),
                    ("terminal", Json::Bool(p.terminal)),
                ])
            })
            .collect();
        let text = Json::arr(rows).to_string_pretty() + "\n";
        let tmp = path.with_extension("json.tmp");
        let write = std::fs::write(&tmp, text)
            .and_then(|()| std::fs::rename(&tmp, path));
        if let Err(e) = write {
            log::warn!("failed to persist {}: {e}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn placement(id: JobId, node: &str, tenant: &str, cost: u64) -> Placement {
        Placement {
            id,
            node: node.to_string(),
            tenant: tenant.to_string(),
            cost,
            spec: format!("{{\"job\":{id}}}"),
            resubmitted: false,
            terminal: false,
        }
    }

    #[test]
    fn accounting_views_skip_terminal_jobs() {
        let t = Table::open(None).unwrap();
        t.insert(placement(1, "a:1", "alice", 100));
        t.insert(placement(2, "a:1", "alice", 200));
        t.insert(placement(3, "b:2", "bob", 400));
        assert_eq!(t.active_for("alice").len(), 2);
        assert_eq!(t.outstanding_cost(), 700);
        assert_eq!(t.active_on("a:1").len(), 2);
        t.mark_terminal(1);
        assert_eq!(t.active_for("alice").len(), 1);
        assert_eq!(t.outstanding_cost(), 600);
        assert_eq!(t.counts(), (3, 2));
        // Terminal jobs stay routable.
        assert_eq!(t.get(1).unwrap().node, "a:1");
    }

    #[test]
    fn reassign_marks_the_resubmit() {
        let t = Table::open(None).unwrap();
        t.insert(placement(7, "a:1", "alice", 10));
        t.reassign(7, "b:2");
        let p = t.get(7).unwrap();
        assert_eq!(p.node, "b:2");
        assert!(p.resubmitted);
        assert_eq!(t.active_on("a:1").len(), 0);
        assert_eq!(t.active_on("b:2").len(), 1);
    }

    #[test]
    fn persists_and_reloads() {
        let dir = std::env::temp_dir().join(format!("pogo_front_table_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        {
            let t = Table::open(Some(&dir)).unwrap();
            t.insert(placement(4, "a:1", "alice", 64));
            t.reassign(4, "b:2");
            t.insert(placement(9, "b:2", "bob", 32));
            t.mark_terminal(9);
        }
        let t = Table::open(Some(&dir)).unwrap();
        assert_eq!(t.next_id_floor(), 10);
        let p = t.get(4).unwrap();
        assert_eq!((p.node.as_str(), p.resubmitted, p.terminal), ("b:2", true, false));
        assert_eq!(p.tenant, "alice");
        assert_eq!(p.spec, "{\"job\":4}");
        let q = t.get(9).unwrap();
        assert!(q.terminal);
        assert_eq!(t.outstanding_cost(), 64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_table_starts_ids_at_one() {
        let t = Table::open(None).unwrap();
        assert_eq!(t.next_id_floor(), 1);
    }
}
