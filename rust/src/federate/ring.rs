//! Rendezvous (highest-random-weight) hashing: job id → backend node.
//!
//! Every placement decision is a pure function of `(node address, job
//! id)`, so any front-door replica — or a fresh one started an hour
//! later — computes the same owner from the same node list with no
//! coordination. Compared to a classic token ring, rendezvous hashing
//! needs no virtual-node bookkeeping and loses only `1/N` of placements
//! when a node leaves: the surviving order of `candidates` is exactly
//! the failover sequence the re-list path walks.

use crate::serve::queue::JobId;

/// 64-bit FNV-1a — the same tiny hash the checkpoint framing uses for
/// its content checksum; deterministic across platforms and builds.
fn fnv1a(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The placement score of one `(node, job)` pair. Higher wins.
fn score(node: &str, job: JobId) -> u64 {
    let h = fnv1a(0xcbf2_9ce4_8422_2325, node.as_bytes());
    fnv1a(h, &job.to_le_bytes())
}

/// The owner of `job` among `nodes` — the highest-scoring node. `None`
/// only when `nodes` is empty. Ties (astronomically unlikely with
/// distinct addresses) break toward the lexicographically smaller
/// address so the answer stays total-ordered and replica-independent.
pub fn owner<'a>(nodes: &'a [String], job: JobId) -> Option<&'a str> {
    nodes
        .iter()
        .max_by(|a, b| {
            score(a, job).cmp(&score(b, job)).then_with(|| b.as_str().cmp(a.as_str()))
        })
        .map(|s| s.as_str())
}

/// All of `nodes` ordered by descending placement score for `job`: the
/// first entry is the owner, the rest are the failover sequence a
/// re-list walks when the owner is down.
pub fn candidates(nodes: &[String], job: JobId) -> Vec<String> {
    let mut ranked: Vec<&String> = nodes.iter().collect();
    ranked.sort_by(|a, b| {
        score(b, job).cmp(&score(a, job)).then_with(|| a.as_str().cmp(b.as_str()))
    });
    ranked.into_iter().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:7070")).collect()
    }

    #[test]
    fn placement_is_deterministic_and_node_order_free() {
        let a = nodes(5);
        let mut b = a.clone();
        b.reverse();
        for job in 0..200u64 {
            assert_eq!(owner(&a, job), owner(&b, job), "job {job}");
            assert_eq!(candidates(&a, job), candidates(&b, job), "job {job}");
        }
    }

    #[test]
    fn candidates_lead_with_the_owner_and_cover_every_node() {
        let ns = nodes(4);
        for job in 0..50u64 {
            let ranked = candidates(&ns, job);
            assert_eq!(ranked.len(), ns.len());
            assert_eq!(Some(ranked[0].as_str()), owner(&ns, job));
            let mut sorted = ranked.clone();
            sorted.sort();
            let mut all = ns.clone();
            all.sort();
            assert_eq!(sorted, all, "every node appears exactly once");
        }
    }

    #[test]
    fn load_spreads_across_nodes() {
        let ns = nodes(4);
        let mut counts = vec![0usize; ns.len()];
        for job in 0..4000u64 {
            let o = owner(&ns, job).unwrap();
            counts[ns.iter().position(|n| n == o).unwrap()] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (500..=1500).contains(&c),
                "node {i} got {c} of 4000 placements — far from uniform: {counts:?}"
            );
        }
    }

    #[test]
    fn removing_a_node_only_moves_its_own_jobs() {
        let all = nodes(4);
        let survivors: Vec<String> = all[..3].to_vec();
        for job in 0..500u64 {
            let before = owner(&all, job).unwrap().to_string();
            let after = owner(&survivors, job).unwrap().to_string();
            if before != *all.last().unwrap() {
                assert_eq!(before, after, "job {job} moved although its owner survived");
            } else {
                // Orphaned jobs land on their next-ranked candidate.
                assert_eq!(
                    after,
                    candidates(&all, job)[1].clone(),
                    "job {job} must fail over to its second candidate"
                );
            }
        }
    }

    #[test]
    fn empty_node_list_has_no_owner() {
        assert_eq!(owner(&[], 7), None);
        assert!(candidates(&[], 7).is_empty());
    }
}
