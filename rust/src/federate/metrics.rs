//! Front-door counters and gauges, rendered as Prometheus text for the
//! front's own `GET /metrics` (the backends keep their `pogo_serve_*`
//! families; everything here is `pogo_front_*`).

use super::registry::{Node, NodeState};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

pub struct FrontMetrics {
    started: Instant,
    /// Requests proxied to a backend (any route).
    pub proxied: AtomicU64,
    /// Submissions placed through the hash ring.
    pub submitted: AtomicU64,
    /// Jobs re-listed from a down node onto the next ring candidate.
    pub relists: AtomicU64,
    /// Probe attempts that failed (before and after a Down transition).
    pub probe_failures: AtomicU64,
    /// SSE relays that reconnected after a backend dropped mid-stream.
    pub sse_reconnects: AtomicU64,
    /// Global-admission rejections by cause.
    pub rejected_quota: AtomicU64,
    pub rejected_cost: AtomicU64,
}

impl Default for FrontMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl FrontMetrics {
    pub fn new() -> FrontMetrics {
        FrontMetrics {
            started: Instant::now(),
            proxied: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            relists: AtomicU64::new(0),
            probe_failures: AtomicU64::new(0),
            sse_reconnects: AtomicU64::new(0),
            rejected_quota: AtomicU64::new(0),
            rejected_cost: AtomicU64::new(0),
        }
    }

    /// Render the exposition text. `nodes` is the registry snapshot;
    /// `(tracked, active)` the placement-table counts.
    pub fn render(&self, nodes: &[Node], tracked: usize, active: usize) -> String {
        fn metric(out: &mut String, name: &str, kind: &str, help: &str, value: f64) {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        }
        let mut out = String::with_capacity(2048);
        metric(
            &mut out,
            "pogo_front_uptime_seconds",
            "gauge",
            "Seconds since the front door started.",
            self.started.elapsed().as_secs_f64(),
        );
        // Per-backend liveness — the gauge the failover proof asserts on.
        out.push_str(
            "# HELP pogo_front_backend_up Backend liveness (1 up, 0 down) by address.\n\
             # TYPE pogo_front_backend_up gauge\n",
        );
        for n in nodes {
            let up = (n.state != NodeState::Down) as u8;
            out.push_str(&format!(
                "pogo_front_backend_up{{backend=\"{}\"}} {up}\n",
                n.addr
            ));
        }
        out.push_str(
            "# HELP pogo_front_backend_state Backend state by address (1 = in this state).\n\
             # TYPE pogo_front_backend_state gauge\n",
        );
        for n in nodes {
            for state in ["up", "draining", "down"] {
                out.push_str(&format!(
                    "pogo_front_backend_state{{backend=\"{}\",state=\"{state}\"}} {}\n",
                    n.addr,
                    (n.state.name() == state) as u8
                ));
            }
        }
        metric(
            &mut out,
            "pogo_front_jobs_tracked",
            "gauge",
            "Placements in the routing table (terminal included).",
            tracked as f64,
        );
        metric(
            &mut out,
            "pogo_front_jobs_active",
            "gauge",
            "Non-terminal placements counted against global admission.",
            active as f64,
        );
        metric(
            &mut out,
            "pogo_front_proxied_total",
            "counter",
            "Requests proxied to a backend.",
            self.proxied.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_front_jobs_submitted_total",
            "counter",
            "Jobs placed through the hash ring.",
            self.submitted.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_front_relists_total",
            "counter",
            "Jobs re-listed from a down backend onto the next ring candidate.",
            self.relists.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_front_probe_failures_total",
            "counter",
            "Health probes that failed.",
            self.probe_failures.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_front_sse_reconnects_total",
            "counter",
            "SSE relays resumed after a backend dropped mid-stream.",
            self.sse_reconnects.load(Ordering::Relaxed) as f64,
        );
        out.push_str(
            "# HELP pogo_front_admission_rejected_total Submissions refused by global \
             admission, by cause.\n# TYPE pogo_front_admission_rejected_total counter\n",
        );
        for (cause, counter) in
            [("quota", &self.rejected_quota), ("cost", &self.rejected_cost)]
        {
            out.push_str(&format!(
                "pogo_front_admission_rejected_total{{cause=\"{cause}\"}} {}\n",
                counter.load(Ordering::Relaxed)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_backend_gauges_and_counters() {
        let m = FrontMetrics::new();
        m.relists.fetch_add(2, Ordering::Relaxed);
        m.rejected_quota.fetch_add(1, Ordering::Relaxed);
        let nodes = vec![
            Node {
                addr: "a:1".to_string(),
                state: NodeState::Up,
                failures: 0,
                last_error: None,
            },
            Node {
                addr: "b:2".to_string(),
                state: NodeState::Down,
                failures: 3,
                last_error: Some("x".into()),
            },
        ];
        let text = m.render(&nodes, 5, 3);
        for want in [
            "pogo_front_backend_up{backend=\"a:1\"} 1",
            "pogo_front_backend_up{backend=\"b:2\"} 0",
            "pogo_front_backend_state{backend=\"b:2\",state=\"down\"} 1",
            "pogo_front_relists_total 2",
            "pogo_front_jobs_tracked 5",
            "pogo_front_jobs_active 3",
            "pogo_front_admission_rejected_total{cause=\"quota\"} 1",
            "pogo_front_admission_rejected_total{cause=\"cost\"} 0",
        ] {
            assert!(text.contains(want), "missing {want} in:\n{text}");
        }
    }
}
