//! `pogo front` — a federated front door for [`crate::serve`].
//!
//! One or more front daemons sit in front of N backend `pogo serve`
//! daemons and present the **same v2 wire contract** clients already
//! speak; pointing a client at a front instead of a backend changes
//! nothing about the bytes it sends or receives. Behind that surface:
//!
//! - [`ring`] — rendezvous (highest-random-weight) hashing of job id →
//!   backend. Pure and deterministic: every front replica computes the
//!   same placement from the same node list, with no coordination and
//!   minimal reshuffling when a node leaves.
//! - [`registry`] — the probed node state machine (`Up` / `Draining` /
//!   `Down`).
//! - [`table`] — the replicated job state: placement, tenant, cost, and
//!   the verbatim spec each job can be re-listed from.
//! - [`admission`] — the global half of split admission (per-tenant
//!   quotas and cost caps across all shards; backends keep their local
//!   caps).
//! - [`proxy`] — pooled keep-alive connections to the backends plus the
//!   response pass-through filter.
//! - [`metrics`] — `pogo_front_*` Prometheus families.
//! - [`front`] — the daemon tying it together: routing, placement,
//!   SSE relay with reconnect, probe loop, and down-node re-listing.

pub mod admission;
pub mod front;
pub mod metrics;
pub mod proxy;
pub mod registry;
pub mod ring;
pub mod table;

pub use admission::FrontAdmission;
pub use front::{Front, FrontConfig};
pub use registry::{Node, NodeState, Probe, Registry};
pub use table::{Placement, Table};
