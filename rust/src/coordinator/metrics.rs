//! Metric recording: wall-clock-stamped series, CSV/JSONL sinks.
//!
//! Every experiment driver records through a `MetricLog`; the figure
//! harness (`pogo run figN`) turns logs into the paper's plots' underlying
//! CSVs (results/figN_*.csv) so the series can be compared directly
//! against the published curves.

use crate::util::json::Json;
use crate::util::Stopwatch;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// One record: step index, seconds since run start, named values.
#[derive(Clone, Debug)]
pub struct Record {
    pub step: usize,
    pub wall_s: f64,
    pub values: BTreeMap<String, f64>,
}

/// An append-only metric log for one run.
pub struct MetricLog {
    /// Run label (method name, usually).
    pub label: String,
    clock: Stopwatch,
    records: Vec<Record>,
}

impl MetricLog {
    pub fn new(label: impl Into<String>) -> Self {
        MetricLog { label: label.into(), clock: Stopwatch::start(), records: Vec::new() }
    }

    /// Record values at a step (wall time stamped automatically).
    pub fn record(&mut self, step: usize, values: &[(&str, f64)]) {
        self.records.push(Record {
            step,
            wall_s: self.clock.seconds(),
            values: values.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[Record] {
        &self.records
    }

    /// Last recorded value of a metric.
    pub fn last(&self, key: &str) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.values.get(key).copied())
    }

    /// Best (minimum) value of a metric.
    pub fn min(&self, key: &str) -> Option<f64> {
        self.records
            .iter()
            .filter_map(|r| r.values.get(key).copied())
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.min(v))))
    }

    /// Best (maximum) value of a metric.
    pub fn max(&self, key: &str) -> Option<f64> {
        self.records
            .iter()
            .filter_map(|r| r.values.get(key).copied())
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// Total wall time of the run so far.
    pub fn elapsed(&self) -> f64 {
        self.clock.seconds()
    }

    /// All metric keys seen, sorted.
    pub fn keys(&self) -> Vec<String> {
        let mut set = std::collections::BTreeSet::new();
        for r in &self.records {
            set.extend(r.values.keys().cloned());
        }
        set.into_iter().collect()
    }

    /// Write `step,wall_s,<keys...>` CSV.
    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let keys = self.keys();
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,wall_s,{}", keys.join(","))?;
        for r in &self.records {
            write!(f, "{},{:.6}", r.step, r.wall_s)?;
            for k in &keys {
                match r.values.get(k) {
                    Some(v) => write!(f, ",{v}")?,
                    None => write!(f, ",")?,
                }
            }
            writeln!(f)?;
        }
        Ok(())
    }

    /// Write one JSON object per record (JSONL).
    pub fn write_jsonl(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        for r in &self.records {
            let mut obj: Vec<(&str, Json)> = vec![
                ("label", Json::str(self.label.clone())),
                ("step", Json::num(r.step as f64)),
                ("wall_s", Json::num(r.wall_s)),
            ];
            for (k, v) in &r.values {
                obj.push((k.as_str(), Json::num(*v)));
            }
            writeln!(f, "{}", Json::obj(obj).to_string())?;
        }
        Ok(())
    }
}

/// Linear interpolation of a metric onto a common time grid — how the
/// paper aggregates independent runs onto shared time steps (§C).
pub fn interp_onto_grid(records: &[Record], key: &str, grid: &[f64]) -> Vec<f64> {
    let pts: Vec<(f64, f64)> = records
        .iter()
        .filter_map(|r| r.values.get(key).map(|v| (r.wall_s, *v)))
        .collect();
    grid.iter()
        .map(|&t| {
            if pts.is_empty() {
                return f64::NAN;
            }
            if t <= pts[0].0 {
                return pts[0].1;
            }
            if t >= pts[pts.len() - 1].0 {
                return pts[pts.len() - 1].1;
            }
            let i = pts.partition_point(|(pt, _)| *pt <= t);
            let (t0, v0) = pts[i - 1];
            let (t1, v1) = pts[i];
            if t1 == t0 {
                v0
            } else {
                v0 + (v1 - v0) * (t - t0) / (t1 - t0)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut log = MetricLog::new("test");
        log.record(0, &[("loss", 10.0), ("dist", 0.1)]);
        log.record(1, &[("loss", 5.0)]);
        log.record(2, &[("loss", 7.0), ("dist", 0.05)]);
        assert_eq!(log.len(), 3);
        assert_eq!(log.last("loss"), Some(7.0));
        assert_eq!(log.min("loss"), Some(5.0));
        assert_eq!(log.max("loss"), Some(10.0));
        assert_eq!(log.last("dist"), Some(0.05));
        assert_eq!(log.keys(), vec!["dist".to_string(), "loss".to_string()]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut log = MetricLog::new("csv");
        log.record(0, &[("a", 1.0)]);
        log.record(1, &[("a", 2.0), ("b", 3.0)]);
        let dir = std::env::temp_dir().join("pogo_test_metrics");
        let path = dir.join("m.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "step,wall_s,a,b");
        assert_eq!(lines.len(), 3);
        assert!(lines[1].ends_with(",1,")); // missing b → empty cell
    }

    #[test]
    fn jsonl_parses_back() {
        let mut log = MetricLog::new("jl");
        log.record(0, &[("x", 0.5)]);
        let dir = std::env::temp_dir().join("pogo_test_metrics");
        let path = dir.join("m.jsonl");
        log.write_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(j.get("x").as_f64(), Some(0.5));
        assert_eq!(j.get("label").as_str(), Some("jl"));
    }

    #[test]
    fn interpolation_matches_linear() {
        let recs = vec![
            Record { step: 0, wall_s: 0.0, values: [("v".to_string(), 0.0)].into() },
            Record { step: 1, wall_s: 2.0, values: [("v".to_string(), 4.0)].into() },
        ];
        let out = interp_onto_grid(&recs, "v", &[-1.0, 0.0, 0.5, 1.0, 2.0, 3.0]);
        assert_eq!(out, vec![0.0, 0.0, 1.0, 2.0, 4.0, 4.0]);
    }
}
