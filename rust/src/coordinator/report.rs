//! Report generation: read the `results/*.csv` series back and print
//! paper-style comparison tables (`pogo report`). Lets a user inspect any
//! past run without re-running experiments, and is what EXPERIMENTS.md's
//! tables were produced from.
//!
//! Also picks up the machine-readable benchmark reports —
//! `BENCH_scale.json`, `BENCH_born.json`, `BENCH_kernels.json`,
//! `BENCH_pool.json`, `BENCH_serve.json`, `BENCH_front.json` and
//! `BENCH_artifact.json` —
//! from the results directory or the repo root,
//! so one `pogo report` shows training series and engine/daemon
//! performance side by side, and (with `--artifact-dir`) summarizes a
//! content-addressed artifact store.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One parsed CSV series.
#[derive(Debug)]
pub struct Series {
    /// File stem, e.g. "fig4-pca_pogo_xla__rep0".
    pub name: String,
    pub columns: Vec<String>,
    /// Row-major values, NaN for empty cells.
    pub rows: Vec<Vec<f64>>,
}

impl Series {
    pub fn parse(path: &Path) -> Result<Series> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty csv")?;
        let columns: Vec<String> = header.split(',').map(str::to_string).collect();
        let mut rows = Vec::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            rows.push(
                line.split(',')
                    .map(|c| c.parse::<f64>().unwrap_or(f64::NAN))
                    .collect(),
            );
        }
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("series")
            .to_string();
        Ok(Series { name, columns, rows })
    }

    fn col_idx(&self, key: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == key)
    }

    /// Last finite value of a column.
    pub fn last(&self, key: &str) -> Option<f64> {
        let i = self.col_idx(key)?;
        self.rows.iter().rev().find_map(|r| {
            let v = *r.get(i)?;
            v.is_finite().then_some(v)
        })
    }

    /// Minimum finite value of a column.
    pub fn min(&self, key: &str) -> Option<f64> {
        let i = self.col_idx(key)?;
        self.rows
            .iter()
            .filter_map(|r| r.get(i).copied().filter(|v| v.is_finite()))
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.min(v))))
    }

    /// Maximum finite value of a column.
    pub fn max(&self, key: &str) -> Option<f64> {
        let i = self.col_idx(key)?;
        self.rows
            .iter()
            .filter_map(|r| r.get(i).copied().filter(|v| v.is_finite()))
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// Total wall time (max wall_s).
    pub fn wall(&self) -> Option<f64> {
        self.max("wall_s")
    }
}

/// Group `results/` CSVs by experiment prefix and print summary tables.
pub fn report(dir: &Path, filter: Option<&str>) -> Result<()> {
    let mut by_experiment: BTreeMap<String, Vec<Series>> = BTreeMap::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?;
    for e in entries {
        let path = e?.path();
        if path.extension().and_then(|x| x.to_str()) != Some("csv") {
            continue;
        }
        let stem = path.file_stem().unwrap().to_string_lossy().to_string();
        if let Some(f) = filter {
            if !stem.contains(f) {
                continue;
            }
        }
        // Experiment prefix = up to the first '_'.
        let exp = stem.split('_').next().unwrap_or("misc").to_string();
        match Series::parse(&path) {
            Ok(s) => by_experiment.entry(exp).or_default().push(s),
            Err(err) => eprintln!("skipping {}: {err}", path.display()),
        }
    }
    let bench_lines = bench_report_lines(dir);
    if by_experiment.is_empty() && bench_lines.is_empty() {
        println!("no series found in {} — run an experiment first", dir.display());
        return Ok(());
    }

    for (exp, mut series) in by_experiment {
        series.sort_by(|a, b| a.name.cmp(&b.name));
        println!("\n== {exp} ({} series) ==", series.len());
        // Union of the interesting metric columns present.
        let metrics = ["gap", "test_acc", "bpd", "loss", "distance", "us_per_matrix"];
        print!("{:<42} {:>9}", "series", "wall");
        let present: Vec<&str> = metrics
            .iter()
            .copied()
            .filter(|m| series.iter().any(|s| s.col_idx(m).is_some()))
            .collect();
        for m in &present {
            print!(" {:>13}", format!("best {m}"));
        }
        println!();
        for s in &series {
            print!(
                "{:<42} {:>9}",
                s.name,
                s.wall().map(crate::util::fmt_duration).unwrap_or_else(|| "-".into())
            );
            for m in &present {
                let v = if *m == "test_acc" { s.max(m) } else { s.min(m) };
                match v {
                    Some(v) if v.abs() < 1e-3 || v.abs() >= 1e4 => print!(" {v:>13.3e}"),
                    Some(v) => print!(" {v:>13.4}"),
                    None => print!(" {:>13}", "-"),
                }
            }
            println!();
        }
    }
    if !bench_lines.is_empty() {
        println!("\n== benchmark reports (BENCH_*.json) ==");
        for line in &bench_lines {
            println!("{line}");
        }
    }
    Ok(())
}

/// Printable summaries of every `BENCH_*.json` found in `dir` or the
/// repo root (deduplicated when they are the same directory).
pub fn bench_report_lines(dir: &Path) -> Vec<String> {
    let mut lines = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for d in [dir.to_path_buf(), crate::repo_root()] {
        for name in [
            "BENCH_scale.json",
            "BENCH_born.json",
            "BENCH_kernels.json",
            "BENCH_pool.json",
            "BENCH_serve.json",
            "BENCH_front.json",
            "BENCH_artifact.json",
        ] {
            let path = d.join(name);
            if !path.is_file() || !seen.insert(path.clone()) {
                continue;
            }
            match Json::parse_file(&path) {
                Ok(j) => lines.extend(summarize_bench(name, &path, &j)),
                Err(e) => lines.push(format!("{}: unreadable ({e:#})", path.display())),
            }
        }
    }
    lines
}

fn summarize_bench(name: &str, path: &Path, j: &Json) -> Vec<String> {
    let mut out = vec![format!("-- {} --", path.display())];
    if name == "BENCH_serve.json" {
        for row in j.get("rows").as_arr().unwrap_or(&[]) {
            let mut line = format!(
                "  {:>3} client(s): {:8.2} jobs/s   p50 {:8.1} ms   p95 {:8.1} ms",
                row.get("clients").as_usize().unwrap_or(0),
                row.get("jobs_per_s").as_f64().unwrap_or(f64::NAN),
                row.get("p50_ms").as_f64().unwrap_or(f64::NAN),
                row.get("p95_ms").as_f64().unwrap_or(f64::NAN),
            );
            // Streaming-client percentiles (rows written before the SSE
            // client existed simply omit them).
            if let Some(sp50) = row.get("stream_p50_ms").as_f64() {
                line.push_str(&format!(
                    "   sse p50 {sp50:8.1} ms   p95 {:8.1} ms",
                    row.get("stream_p95_ms").as_f64().unwrap_or(f64::NAN),
                ));
            }
            out.push(line);
        }
    } else if name == "BENCH_front.json" {
        for row in j.get("rows").as_arr().unwrap_or(&[]) {
            out.push(format!(
                "  {:>3} client(s): front {:8.2} jobs/s (p50 {:7.1} / p95 {:7.1} ms)   \
                 direct {:8.2} jobs/s (p50 {:7.1} / p95 {:7.1} ms)",
                row.get("clients").as_usize().unwrap_or(0),
                row.get("front_jobs_per_s").as_f64().unwrap_or(f64::NAN),
                row.get("front_p50_ms").as_f64().unwrap_or(f64::NAN),
                row.get("front_p95_ms").as_f64().unwrap_or(f64::NAN),
                row.get("direct_jobs_per_s").as_f64().unwrap_or(f64::NAN),
                row.get("direct_p50_ms").as_f64().unwrap_or(f64::NAN),
                row.get("direct_p95_ms").as_f64().unwrap_or(f64::NAN),
            ));
        }
    } else if name == "BENCH_artifact.json" {
        for row in j.get("rows").as_arr().unwrap_or(&[]) {
            out.push(format!(
                "  {:<8} {:8.2} MiB payload: {:8.2} ms   {:8.1} MiB/s",
                row.get("op").as_str().unwrap_or("?"),
                row.get("payload_mb").as_f64().unwrap_or(f64::NAN),
                row.get("ms").as_f64().unwrap_or(f64::NAN),
                row.get("mb_per_s").as_f64().unwrap_or(f64::NAN),
            ));
        }
    } else if name == "BENCH_kernels.json" {
        if let Some(k) = j.get("kernel").as_str() {
            out.push(format!("  arch microkernel: {k}"));
        }
        if let Some(map) = j.get("speedup_fused_vs_naive").as_obj() {
            for (cell, s) in map {
                out.push(format!(
                    "  {cell:<14} fused {:.2}x naive",
                    s.as_f64().unwrap_or(f64::NAN)
                ));
            }
        }
    } else if name == "BENCH_pool.json" {
        for row in j.get("dispatch").as_arr().unwrap_or(&[]) {
            out.push(format!(
                "  dispatch {:<9} {:>2} shard(s): {:10.0} ns",
                row.get("pool").as_str().unwrap_or("?"),
                row.get("shards").as_usize().unwrap_or(0),
                row.get("ns_per_dispatch").as_f64().unwrap_or(f64::NAN),
            ));
        }
        if let Some(map) = j.get("speedup_resident_vs_spawn").as_obj() {
            for (cell, s) in map {
                out.push(format!(
                    "  {cell:<14} resident {:.2}x spawn",
                    s.as_f64().unwrap_or(f64::NAN)
                ));
            }
        }
    } else if let Some(map) = j.get("speedup_batched_vs_loop").as_obj() {
        for (b, s) in map {
            out.push(format!(
                "  B={b:<6} batched {:.2}x loop",
                s.as_f64().unwrap_or(f64::NAN)
            ));
        }
    }
    out
}

/// Printable summary of a content-addressed artifact store directory
/// (what `pogo report --artifact-dir` appends): count, total bytes, and
/// the largest entries first.
pub fn artifact_store_lines(dir: &Path) -> Vec<String> {
    match crate::artifact::ArtifactStore::open(dir, u64::MAX) {
        Ok(store) => {
            let s = store.summary();
            let mut lines = vec![format!(
                "{}: {} artifact(s), {} bytes",
                dir.display(),
                s.count,
                s.total_bytes
            )];
            for (hash, bytes) in s.entries.iter().take(8) {
                lines.push(format!("  {hash}  {bytes:>12} bytes"));
            }
            if s.count > 8 {
                lines.push(format!("  ... and {} more", s.count - 8));
            }
            lines
        }
        Err(e) => vec![format!("{}: unreadable ({e:#})", dir.display())],
    }
}

/// Printable span tree from a flight-recorder trace — the JSON shape of
/// `GET /v2/jobs/:id/trace` / `JobTrace::tree_json` — indented two
/// spaces per nesting level, with total and self times in ms. What
/// `pogo trace` prints after writing the Chrome trace file.
pub fn trace_summary_lines(trace: &Json) -> Vec<String> {
    fn walk(node: &Json, depth: usize, out: &mut Vec<String>) {
        let name = node.get("name").as_str().unwrap_or("?");
        // Sampled step windows carry their covered range.
        let label = match node.get("steps").as_arr() {
            Some(r) if r.len() == 2 => format!(
                "{name} {}..{}",
                r[0].as_usize().unwrap_or(0),
                r[1].as_usize().unwrap_or(0)
            ),
            _ => name.to_string(),
        };
        let dur_ms = node.get("dur_us").as_f64().unwrap_or(0.0) / 1000.0;
        let self_ms = node.get("self_us").as_f64().unwrap_or(0.0) / 1000.0;
        let indented = format!("{:indent$}{label}", "", indent = depth * 2);
        out.push(format!("{indented:<28} {dur_ms:>10.3} ms  (self {self_ms:.3} ms)"));
        if let Some(children) = node.get("children").as_arr() {
            for c in children {
                walk(c, depth + 1, out);
            }
        }
    }
    let mut out = Vec::new();
    match trace.get("spans").as_arr() {
        Some(spans) if !spans.is_empty() => {
            for s in spans {
                walk(s, 0, &mut out);
            }
        }
        _ => out.push("(no spans recorded — is POGO_OBS off?)".to_string()),
    }
    if let Some(dropped) = trace.get("dropped").as_usize() {
        if dropped > 0 {
            out.push(format!("({dropped} inner spans dropped past the buffer cap)"));
        }
    }
    out
}

/// Machine-readable report (one JSON object per series) for tooling.
pub fn report_json(dir: &Path) -> Result<String> {
    let mut out = Vec::new();
    for e in std::fs::read_dir(dir)? {
        let path = e?.path();
        if path.extension().and_then(|x| x.to_str()) != Some("csv") {
            continue;
        }
        if let Ok(s) = Series::parse(&path) {
            out.push(Json::obj(vec![
                ("name", Json::str(s.name.clone())),
                ("rows", Json::num(s.rows.len() as f64)),
                ("wall_s", s.wall().map(Json::num).unwrap_or(Json::Null)),
                ("best_gap", s.min("gap").map(Json::num).unwrap_or(Json::Null)),
                ("best_acc", s.max("test_acc").map(Json::num).unwrap_or(Json::Null)),
                ("best_bpd", s.min("bpd").map(Json::num).unwrap_or(Json::Null)),
                ("final_distance",
                 s.last("distance").map(Json::num).unwrap_or(Json::Null)),
            ]));
        }
    }
    Ok(Json::arr(out).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_csv(dir: &Path, name: &str, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join(name), text).unwrap();
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("pogo_report_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_and_summarizes() {
        let d = tmpdir("basic");
        write_csv(&d, "figx_pogo_rep0.csv",
                  "step,wall_s,gap,distance\n1,0.1,0.5,1e-6\n2,0.2,0.1,2e-6\n");
        write_csv(&d, "figx_rgd_rep0.csv",
                  "step,wall_s,gap,distance\n1,0.5,0.6,\n2,1.0,0.2,3e-6\n");
        let s = Series::parse(&d.join("figx_pogo_rep0.csv")).unwrap();
        assert_eq!(s.min("gap"), Some(0.1));
        assert_eq!(s.last("distance"), Some(2e-6));
        assert_eq!(s.wall(), Some(0.2));
        report(&d, None).unwrap();
        report(&d, Some("pogo")).unwrap();
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn trace_summary_renders_an_indented_tree() {
        let t = crate::obs::JobTrace::new();
        t.record_span("admit", 0, 2, 1);
        t.record_span("queued", 2, 8, 1);
        t.record_span_full("steps", 12, 40, 3, Some((0, 8)));
        t.record_span("steps", 12, 83, 2);
        t.record_span("run", 10, 90, 1);
        t.record_span("job", 0, 100, 0);
        let lines = trace_summary_lines(&t.tree_json());
        assert_eq!(lines.len(), 6);
        assert!(lines[0].starts_with("job"), "{lines:?}");
        assert!(lines[1].starts_with("  admit"), "{lines:?}");
        assert!(lines[3].starts_with("  run"), "{lines:?}");
        assert!(lines[4].starts_with("    steps"), "{lines:?}");
        assert!(lines[5].contains("steps 0..8"), "window range in the label: {lines:?}");
        assert!(lines[0].contains("0.100 ms"), "total in ms: {lines:?}");
        // An empty trace says so instead of printing nothing.
        let empty = trace_summary_lines(&crate::obs::JobTrace::new().tree_json());
        assert_eq!(empty.len(), 1);
        assert!(empty[0].contains("no spans"), "{empty:?}");
    }

    #[test]
    fn empty_cells_are_nan_but_skipped() {
        let d = tmpdir("nan");
        write_csv(&d, "f_a_rep0.csv", "step,wall_s,gap\n1,0.1,\n2,0.2,0.3\n");
        let s = Series::parse(&d.join("f_a_rep0.csv")).unwrap();
        assert_eq!(s.min("gap"), Some(0.3));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bench_reports_picked_up() {
        let d = tmpdir("bench");
        std::fs::write(
            d.join("BENCH_serve.json"),
            r#"{"unit": "jobs_per_s_and_latency_ms",
                "rows": [{"clients": 4, "jobs": 8, "jobs_per_s": 11.5,
                          "p50_ms": 40.5, "p95_ms": 92.0}]}"#,
        )
        .unwrap();
        std::fs::write(
            d.join("BENCH_scale.json"),
            r#"{"unit": "us_per_matrix_step", "records": [],
                "speedup_batched_vs_loop": {"4096": 2.5}}"#,
        )
        .unwrap();
        std::fs::write(
            d.join("BENCH_front.json"),
            r#"{"unit": "jobs_per_s_and_latency_ms",
                "rows": [{"clients": 4, "jobs": 16,
                          "front_jobs_per_s": 10.2, "front_p50_ms": 44.0,
                          "front_p95_ms": 101.0,
                          "direct_jobs_per_s": 11.5, "direct_p50_ms": 40.5,
                          "direct_p95_ms": 92.0}]}"#,
        )
        .unwrap();
        std::fs::write(
            d.join("BENCH_artifact.json"),
            r#"{"unit": "ms_and_mib_per_s",
                "rows": [{"op": "seal", "payload_mb": 8.0, "ms": 12.5,
                          "mb_per_s": 640.0}]}"#,
        )
        .unwrap();
        std::fs::write(
            d.join("BENCH_kernels.json"),
            r#"{"unit": "us_per_matrix_step", "kernel": "avx2", "records": [],
                "speedup_fused_vs_naive": {"16x16@4096": 2.1}}"#,
        )
        .unwrap();
        std::fs::write(
            d.join("BENCH_pool.json"),
            r#"{"unit": "ns_per_dispatch_and_us_per_step",
                "dispatch": [{"pool": "resident", "shards": 4,
                              "ns_per_dispatch": 900.0}],
                "records": [],
                "speedup_resident_vs_spawn": {"16x16@4096": 1.3}}"#,
        )
        .unwrap();
        let lines = bench_report_lines(&d);
        let text = lines.join("\n");
        assert!(text.contains("BENCH_serve.json"), "{text}");
        assert!(text.contains("jobs/s"), "{text}");
        assert!(text.contains("B=4096"), "{text}");
        assert!(text.contains("2.50x"), "{text}");
        assert!(text.contains("BENCH_front.json"), "{text}");
        assert!(text.contains("front    10.20 jobs/s"), "{text}");
        assert!(text.contains("direct    11.50 jobs/s"), "{text}");
        assert!(text.contains("BENCH_artifact.json"), "{text}");
        assert!(text.contains("seal"), "{text}");
        assert!(text.contains("MiB/s"), "{text}");
        assert!(text.contains("BENCH_kernels.json"), "{text}");
        assert!(text.contains("arch microkernel: avx2"), "{text}");
        assert!(text.contains("16x16@4096"), "{text}");
        assert!(text.contains("fused 2.10x naive"), "{text}");
        assert!(text.contains("BENCH_pool.json"), "{text}");
        assert!(text.contains("dispatch resident"), "{text}");
        assert!(text.contains("resident 1.30x spawn"), "{text}");
        // report() itself must not choke on a dir holding only bench JSON.
        report(&d, None).unwrap();
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn artifact_store_summary_lines() {
        use crate::artifact::{Artifact, ArtifactStore, Provenance};
        use crate::serve::job::JobDomain;
        use crate::serve::problem::{InlineMat, InlineProblem};
        let d = tmpdir("artstore");
        let store = ArtifactStore::open(&d, u64::MAX).unwrap();
        let mut rng = crate::rng::Rng::seed_from_u64(5);
        let inline = InlineProblem::Pca {
            c: vec![InlineMat::from_mat(&crate::linalg::Mat::<f32>::randn(4, 4, &mut rng))],
        };
        let art =
            Artifact::seal(&inline, JobDomain::Real, 1, 2, 4, Provenance::new(5)).unwrap();
        store.insert(&art).unwrap();
        let lines = artifact_store_lines(&d);
        let text = lines.join("\n");
        assert!(text.contains("1 artifact(s)"), "{text}");
        assert!(text.contains(&art.hash()), "{text}");
        // A missing directory is a readable line, not a panic.
        let missing = artifact_store_lines(&d.join("definitely_missing/nested"));
        assert_eq!(missing.len(), 1, "{missing:?}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn json_report_shape() {
        let d = tmpdir("json");
        write_csv(&d, "f_a_rep0.csv", "step,wall_s,gap\n1,0.1,0.2\n");
        let j = report_json(&d).unwrap();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
        std::fs::remove_dir_all(&d).ok();
    }
}
