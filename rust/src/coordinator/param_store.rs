//! Parameter store: named constrained/unconstrained matrices, grouped by
//! shape for batched dispatch — generic over the element [`Field`], so
//! one store type serves real Stiefel parameters (`ParamStore<f32>`, the
//! default) and complex unitary ones (`ParamStore<Complex<f32>>`, the
//! Born-MPS cores of Fig. 8).
//!
//! The shape-grouping is the coordinator's core scalability device (the
//! paper's Fig. 1 regime): 10⁴ orthogonal 3×3 kernels become a handful of
//! `(B, 3, 3)` groups, each updated by ONE XLA dispatch (or one Rust loop),
//! instead of 10⁴ tiny QR calls.

use crate::linalg::{BatchMat, Complex, Field, Mat, Scalar};
use crate::manifold::stiefel;
use crate::rng::Rng;
use std::collections::BTreeMap;

/// How a parameter is constrained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Constraint {
    /// Must remain on St(p, n) — updated by an orthoptimizer.
    Stiefel,
    /// Unconstrained — updated by Adam (or SGD).
    Free,
}

/// One named parameter.
#[derive(Clone, Debug)]
pub struct Param<E: Field = f32> {
    pub name: String,
    pub mat: Mat<E>,
    pub constraint: Constraint,
    /// Batching key: parameters group by (shape, key). Empty by default;
    /// set it to keep logically-distinct collections (e.g. CNN layers) in
    /// separate batched dispatches matching their per-layer artifacts.
    pub group_key: String,
}

/// A shape-homogeneous group of constrained parameters (indices into the
/// store), the unit of batched dispatch.
#[derive(Clone, Debug)]
pub struct Group {
    pub shape: (usize, usize),
    pub key: String,
    pub indices: Vec<usize>,
}

/// The parameter store.
#[derive(Clone, Debug)]
pub struct ParamStore<E: Field = f32> {
    params: Vec<Param<E>>,
}

impl<E: Field> Default for ParamStore<E> {
    fn default() -> Self {
        ParamStore { params: Vec::new() }
    }
}

impl<E: Field> ParamStore<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a Stiefel-constrained parameter (must start feasible —
    /// `X Xᴴ ≈ I` on either field).
    pub fn add_stiefel(&mut self, name: impl Into<String>, mat: Mat<E>) -> usize {
        self.add_stiefel_keyed(name, mat, "")
    }

    /// Register a Stiefel parameter with an explicit batching key.
    pub fn add_stiefel_keyed(
        &mut self,
        name: impl Into<String>,
        mat: Mat<E>,
        key: impl Into<String>,
    ) -> usize {
        let d = stiefel::distance_f(&mat);
        debug_assert!(d < 1e-2, "parameter registered off-manifold: {d}");
        self.params.push(Param {
            name: name.into(),
            mat,
            constraint: Constraint::Stiefel,
            group_key: key.into(),
        });
        self.params.len() - 1
    }

    /// Register an unconstrained parameter.
    pub fn add_free(&mut self, name: impl Into<String>, mat: Mat<E>) -> usize {
        self.params.push(Param {
            name: name.into(),
            mat,
            constraint: Constraint::Free,
            group_key: String::new(),
        });
        self.params.len() - 1
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    pub fn get(&self, idx: usize) -> &Param<E> {
        &self.params[idx]
    }

    pub fn get_mut(&mut self, idx: usize) -> &mut Param<E> {
        &mut self.params[idx]
    }

    pub fn mat(&self, idx: usize) -> &Mat<E> {
        &self.params[idx].mat
    }

    pub fn params(&self) -> &[Param<E>] {
        &self.params
    }

    /// Partition the *constrained* parameters into (shape, key)-homogeneous
    /// groups (deterministic order: by shape, then key, then registration).
    pub fn stiefel_groups(&self) -> Vec<Group> {
        let mut by_shape: BTreeMap<((usize, usize), String), Vec<usize>> = BTreeMap::new();
        for (i, p) in self.params.iter().enumerate() {
            if p.constraint == Constraint::Stiefel {
                by_shape.entry((p.mat.shape(), p.group_key.clone())).or_default().push(i);
            }
        }
        by_shape
            .into_iter()
            .map(|((shape, key), indices)| Group { shape, key, indices })
            .collect()
    }

    /// Indices of unconstrained parameters.
    pub fn free_indices(&self) -> Vec<usize> {
        self.params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.constraint == Constraint::Free)
            .map(|(i, _)| i)
            .collect()
    }

    /// Clone the matrices of a group (batch extraction for dispatch).
    pub fn extract_group(&self, g: &Group) -> Vec<Mat<E>> {
        g.indices.iter().map(|&i| self.params[i].mat.clone()).collect()
    }

    /// Pack a group's matrices into one contiguous `(B, p, n)` tensor —
    /// the batched engine's unit of dispatch (no per-matrix allocations).
    /// Works on either field: complex groups pack interleaved
    /// `Complex<S>` entries, exactly what `BatchedHost<Complex<S>>` steps.
    pub fn extract_group_batch(&self, g: &Group) -> BatchMat<E> {
        let (p, n) = g.shape;
        let mut batch = BatchMat::zeros(g.indices.len(), p, n);
        for (bi, &i) in g.indices.iter().enumerate() {
            batch.set_mat(bi, &self.params[i].mat);
        }
        batch
    }

    /// Write a stepped `(B, p, n)` tensor back into a group's parameters.
    pub fn write_group_batch(&mut self, g: &Group, batch: &BatchMat<E>) {
        assert_eq!(batch.batch(), g.indices.len(), "batch size vs group size");
        for (bi, &i) in g.indices.iter().enumerate() {
            let m = &mut self.params[i].mat;
            debug_assert_eq!(m.shape(), batch.mat_shape());
            m.as_mut_slice().copy_from_slice(batch.mat(bi));
        }
    }

    /// Write updated matrices back into a group.
    pub fn write_group(&mut self, g: &Group, mats: Vec<Mat<E>>) {
        assert_eq!(mats.len(), g.indices.len());
        for (&i, m) in g.indices.iter().zip(mats) {
            debug_assert_eq!(self.params[i].mat.shape(), m.shape());
            self.params[i].mat = m;
        }
    }

    /// Max manifold distance across all constrained parameters — the
    /// feasibility telemetry of every figure (`‖X Xᴴ − I‖` on either
    /// field).
    pub fn max_stiefel_distance(&self) -> f64 {
        self.params
            .iter()
            .filter(|p| p.constraint == Constraint::Stiefel)
            .map(|p| stiefel::distance_f(&p.mat))
            .fold(0.0, f64::max)
    }

    /// Max *normalized* distance ‖XXᴴ−I‖/√p (Fig. 6's metric).
    pub fn max_normalized_distance(&self) -> f64 {
        self.params
            .iter()
            .filter(|p| p.constraint == Constraint::Stiefel)
            .map(|p| stiefel::normalized_distance(&p.mat))
            .fold(0.0, f64::max)
    }

    /// Total parameter count (scalars — complex entries count once).
    pub fn num_scalars(&self) -> usize {
        self.params.iter().map(|p| p.mat.len()).sum()
    }
}

/// Real-only conveniences (QR-based random points).
impl<S: Scalar> ParamStore<S> {
    /// Register `count` random Stiefel matrices of one shape
    /// (`name_0 … name_{count−1}`), batch-keyed by `name`. Returns indices.
    pub fn add_stiefel_group(
        &mut self,
        name: &str,
        count: usize,
        p: usize,
        n: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        (0..count)
            .map(|i| {
                self.add_stiefel_keyed(
                    format!("{name}_{i}"),
                    stiefel::random_point_t::<S>(p, n, rng),
                    name,
                )
            })
            .collect()
    }
}

/// Complex-only conveniences (polar-projected random unitary points).
impl<S: Scalar> ParamStore<Complex<S>> {
    /// Register `count` random complex-Stiefel (unitary) matrices of one
    /// shape, batch-keyed by `name`. Returns indices.
    pub fn add_unitary_group(
        &mut self,
        name: &str,
        count: usize,
        p: usize,
        n: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        (0..count)
            .map(|i| {
                self.add_stiefel_keyed(
                    format!("{name}_{i}"),
                    stiefel::random_point_complex::<S>(p, n, rng),
                    name,
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::MatF;
    use crate::testing;

    #[test]
    fn groups_partition_constrained_params() {
        let mut rng = Rng::seed_from_u64(0);
        let mut store = ParamStore::new();
        store.add_stiefel_group("k3", 5, 3, 3, &mut rng);
        store.add_stiefel_group("w", 2, 4, 8, &mut rng);
        store.add_free("head", MatF::zeros(7, 7));
        store.add_stiefel_group("k3b", 3, 3, 3, &mut rng);

        let groups = store.stiefel_groups();
        // (3,3) splits into two keyed groups ("k3", "k3b"); (4,8) is one.
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].shape, (3, 3));
        assert_eq!(groups[0].key, "k3");
        assert_eq!(groups[0].indices.len(), 5);
        assert_eq!(groups[1].key, "k3b");
        assert_eq!(groups[1].indices.len(), 3);
        assert_eq!(groups[2].shape, (4, 8));
        assert_eq!(groups[2].indices.len(), 2);
        // Exact cover of constrained indices, no duplicates, no free.
        let mut all: Vec<usize> = groups.iter().flat_map(|g| g.indices.clone()).collect();
        all.sort_unstable();
        let expected: Vec<usize> =
            (0..store.len()).filter(|&i| store.get(i).constraint == Constraint::Stiefel)
                .collect();
        assert_eq!(all, expected);
    }

    #[test]
    fn batch_extract_write_roundtrip() {
        let mut rng = Rng::seed_from_u64(4);
        let mut store: ParamStore<f32> = ParamStore::new();
        store.add_stiefel_group("g", 5, 3, 6, &mut rng);
        let groups = store.stiefel_groups();
        let mut batch = store.extract_group_batch(&groups[0]);
        assert_eq!(batch.shape(), (5, 3, 6));
        // Matches the per-matrix extraction exactly.
        for (bi, m) in store.extract_group(&groups[0]).iter().enumerate() {
            assert_eq!(batch.mat(bi), m.as_slice());
        }
        batch.mat_mut(3).fill(0.0);
        store.write_group_batch(&groups[0], &batch);
        assert_eq!(store.mat(3).norm_sq(), 0.0);
        assert!(store.mat(2).norm_sq() > 0.0);
    }

    #[test]
    fn extract_write_roundtrip() {
        let mut rng = Rng::seed_from_u64(1);
        let mut store = ParamStore::new();
        store.add_stiefel_group("g", 4, 3, 6, &mut rng);
        let groups = store.stiefel_groups();
        let mut mats = store.extract_group(&groups[0]);
        mats[2] = MatF::zeros(3, 6);
        store.write_group(&groups[0], mats);
        assert_eq!(store.mat(2).norm_sq(), 0.0);
        assert!(store.mat(1).norm_sq() > 0.0);
    }

    #[test]
    fn distances_zero_at_init() {
        let mut rng = Rng::seed_from_u64(2);
        let mut store: ParamStore<f32> = ParamStore::new();
        store.add_stiefel_group("g", 3, 4, 9, &mut rng);
        assert!(store.max_stiefel_distance() < 1e-5);
        assert!(store.max_normalized_distance() < 1e-5);
    }

    #[test]
    fn complex_store_groups_and_batches() {
        // The SAME store type over Complex<f32>: unitary groups pack and
        // write back through the identical batch path.
        let mut rng = Rng::seed_from_u64(5);
        let mut store: ParamStore<Complex<f32>> = ParamStore::new();
        store.add_unitary_group("cores", 4, 3, 6, &mut rng);
        store.add_unitary_group("wide", 2, 4, 8, &mut rng);
        assert!(store.max_stiefel_distance() < 1e-4);
        let groups = store.stiefel_groups();
        assert_eq!(groups.len(), 2);
        let mut batch = store.extract_group_batch(&groups[0]);
        assert_eq!(batch.shape(), (4, 3, 6));
        for (bi, m) in store.extract_group(&groups[0]).iter().enumerate() {
            assert_eq!(batch.mat(bi), m.as_slice());
        }
        batch.mat_mut(1).fill(Complex::new(0.0, 0.0));
        store.write_group_batch(&groups[0], &batch);
        assert_eq!(store.mat(1).norm_sq(), 0.0);
        assert!(store.mat(0).norm_sq() > 0.0);
    }

    #[test]
    fn prop_grouping_is_exact_cover() {
        testing::forall(
            "param grouping exact cover",
            10,
            |rng| {
                let mut store = ParamStore::new();
                let n_groups = 1 + rng.index(4);
                for gi in 0..n_groups {
                    let (p, n) = testing::gen_wide_shape(rng, 4, 8);
                    let count = 1 + rng.index(6);
                    store.add_stiefel_group(&format!("g{gi}"), count, p, n, rng);
                    if rng.bernoulli(0.5) {
                        store.add_free(format!("f{gi}"), MatF::zeros(2, 2));
                    }
                }
                store
            },
            |store| {
                let groups = store.stiefel_groups();
                let mut seen = std::collections::BTreeSet::new();
                for g in &groups {
                    for &i in &g.indices {
                        if store.get(i).mat.shape() != g.shape {
                            return Err(format!("index {i} has wrong shape"));
                        }
                        if !seen.insert(i) {
                            return Err(format!("index {i} in two groups"));
                        }
                    }
                }
                let expected: std::collections::BTreeSet<usize> = (0..store.len())
                    .filter(|&i| store.get(i).constraint == Constraint::Stiefel)
                    .collect();
                if seen != expected {
                    return Err("cover mismatch".to_string());
                }
                Ok(())
            },
        );
    }
}
