//! Optimizer specs + engine dispatch.
//!
//! An [`OptimizerSpec`] is the serializable single source of truth for
//! "which method, which hyperparameters, which engine". `build::<S>` turns
//! it into a concrete stepper for one shape group at any scalar precision,
//! choosing between the pure-Rust engine and the XLA (AOT Pallas) engine;
//! `build_unitary::<S>` does the same on the complex Stiefel manifold.
//! Construction itself lives in [`crate::optim::registry`] — the one match
//! over `Method` in the crate — so every construction site (Trainer,
//! experiments, benches, CLI) goes through this file.
//!
//! Specs round-trip through the in-crate `util/json` (`to_json` /
//! `from_json`, byte-identical), which is what makes runs replayable: the
//! experiment drivers emit a `*.spec.json` manifest next to each CSV and
//! the CLI accepts `pogo run --spec <file.json>`.

use crate::linalg::{Complex, KernelChoice};
use crate::optim::base::BaseOptKind;
use crate::optim::pogo::LambdaPolicy;
use crate::optim::registry as methods;
use crate::optim::{Engine, Method, Orthoptimizer};
use crate::runtime::Registry;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Full optimizer description (mirrors the paper's per-method knobs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OptimizerSpec {
    pub method: Method,
    pub lr: f64,
    pub base: BaseOptKind,
    /// POGO λ policy.
    pub lambda: LambdaPolicy,
    /// Landing/LandingPC attraction strength.
    pub attraction: f64,
    /// RSDM submanifold dimension.
    pub submanifold_dim: usize,
    pub seed: u64,
    pub engine: Engine,
    /// Batched-engine execution path (`auto`/`fused`/`naive`) —
    /// bit-identical by the StepKernel contract, so a pure perf knob;
    /// ignored by the loop and XLA engines.
    pub kernel: KernelChoice,
}

impl OptimizerSpec {
    pub fn new(method: Method, lr: f64) -> Self {
        OptimizerSpec {
            method,
            lr,
            base: BaseOptKind::Sgd,
            lambda: LambdaPolicy::Half,
            attraction: 1.0,
            submanifold_dim: 32,
            seed: 0,
            engine: Engine::Rust,
            kernel: KernelChoice::Auto,
        }
    }

    pub fn with_base(mut self, base: BaseOptKind) -> Self {
        self.base = base;
        self
    }

    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_kernel(mut self, kernel: KernelChoice) -> Self {
        self.kernel = kernel;
        self
    }

    pub fn with_lambda(mut self, lambda: LambdaPolicy) -> Self {
        self.lambda = lambda;
        self
    }

    pub fn with_attraction(mut self, a: f64) -> Self {
        self.attraction = a;
        self
    }

    pub fn with_submanifold(mut self, r: usize) -> Self {
        self.submanifold_dim = r;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Display label (method + engine) for figures.
    pub fn label(&self) -> String {
        let eng = match self.engine {
            Engine::Rust => "",
            Engine::BatchedHost => "[batched]",
            Engine::Xla => "[xla]",
        };
        format!("{}{eng}", self.method.name())
    }

    /// Static capabilities of the spec's method.
    pub fn capabilities(&self) -> crate::optim::registry::Capabilities {
        methods::capabilities(self.method)
    }

    /// Build a stepper for one `(group_size, p, n)` group at scalar
    /// precision `S` (`f32` is the experiment default; the precision
    /// ablation builds `f64`).
    ///
    /// `registry` is required for `Engine::Xla`; the artifact for the
    /// group shape must exist (aot.py emits one per experiment shape).
    /// The XLA engine is f32-only — requesting it at another precision is
    /// an error, not a silent fallback. `Engine::BatchedHost` packs the
    /// whole group into one `(B, p, n)` tensor and is scalar-generic like
    /// the per-matrix host engine.
    pub fn build<S: crate::linalg::Scalar>(
        &self,
        registry: Option<&Registry>,
        group: (usize, usize, usize),
    ) -> Result<Box<dyn Orthoptimizer<S>>> {
        let (b, p, n) = group;
        match self.engine {
            Engine::Xla => {
                let reg = registry.ok_or_else(|| anyhow!("XLA engine needs a registry"))?;
                let stepper = methods::build_xla(self, reg, b, p, n)?;
                into_scalar_engine::<S>(Box::new(stepper)).ok_or_else(|| {
                    anyhow!(
                        "XLA engine only supports f32 (requested {})",
                        std::any::type_name::<S>()
                    )
                })
            }
            Engine::BatchedHost => methods::build_batched_host::<S>(self),
            Engine::Rust => methods::build_host::<S>(self, b),
        }
    }

    /// Build a complex-Stiefel (unitary) optimizer for `n_params`
    /// matrices, honouring `self.engine` like the real path: `rust` is
    /// the per-matrix loop, `batched-host` the packed
    /// `BatchedHost<Complex<S>>` (the Fig. 8 thousands-of-unitaries fast
    /// path; state is batch-wide, so give it one shape-homogeneous group
    /// — `OptimSession::new_unitary` does). The XLA engine is not wired
    /// for the complex domain (the tiny Born cores make complex XLA
    /// dispatch overhead-bound) and errors instead of silently falling
    /// back.
    pub fn build_unitary<S: crate::linalg::Scalar>(
        &self,
        n_params: usize,
    ) -> Result<Box<dyn Orthoptimizer<Complex<S>>>> {
        match self.engine {
            Engine::Rust => methods::build_unitary::<S>(self, n_params),
            Engine::BatchedHost => methods::build_batched_host_unitary::<S>(self),
            Engine::Xla => Err(anyhow!(
                "the XLA engine has no complex-Stiefel path; use 'rust' or 'batched-host'"
            )),
        }
    }

    // ---- Serialization (util/json; keys sorted ⇒ deterministic) ---------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(self.method.name())),
            ("lr", Json::num(self.lr)),
            ("base", self.base.to_json()),
            ("lambda", Json::str(self.lambda.name())),
            ("attraction", Json::num(self.attraction)),
            ("submanifold_dim", Json::num(self.submanifold_dim as f64)),
            // Seeds are u64; JSON numbers are f64 (2^53) — keep exact.
            ("seed", Json::str(self.seed.to_string())),
            ("engine", Json::str(self.engine.name())),
            ("kernel", Json::str(self.kernel.name())),
        ])
    }

    /// Parse a spec. `method` and `lr` are required; every other field
    /// falls back to the [`OptimizerSpec::new`] default, so hand-written
    /// spec files can stay minimal. Fields that are *present* but
    /// malformed are errors — a replayed manifest must never silently
    /// run with different hyperparameters than it states.
    pub fn from_json(j: &Json) -> Result<OptimizerSpec> {
        let method = match j.get("method") {
            Json::Null => return Err(anyhow!("spec: missing 'method'")),
            v => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow!("spec: 'method' must be a string"))?;
                Method::parse(s).ok_or_else(|| anyhow!("spec: unknown method '{s}'"))?
            }
        };
        let lr = j
            .get("lr")
            .as_f64()
            .ok_or_else(|| anyhow!("spec: missing or non-numeric 'lr'"))?;
        let mut spec = OptimizerSpec::new(method, lr);
        if !matches!(j.get("base"), Json::Null) {
            spec.base = BaseOptKind::from_json(j.get("base"))?;
        }
        match j.get("lambda") {
            Json::Null => {}
            v => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow!("spec: 'lambda' must be a string"))?;
                spec.lambda = LambdaPolicy::parse(s)
                    .ok_or_else(|| anyhow!("spec: unknown lambda policy '{s}'"))?;
            }
        }
        match j.get("attraction") {
            Json::Null => {}
            v => {
                spec.attraction = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("spec: 'attraction' must be a number"))?;
            }
        }
        match j.get("submanifold_dim") {
            Json::Null => {}
            v => {
                spec.submanifold_dim = v.as_usize().ok_or_else(|| {
                    anyhow!("spec: 'submanifold_dim' must be a non-negative integer")
                })?;
            }
        }
        match j.get("seed") {
            Json::Null => {}
            Json::Str(s) => {
                spec.seed = s
                    .parse::<u64>()
                    .map_err(|_| anyhow!("spec: 'seed' is not a u64: '{s}'"))?;
            }
            Json::Num(v) => {
                // f64 is only exact up to 2^53; larger seeds must use the
                // string form `to_json` emits.
                if *v < 0.0 || v.fract() != 0.0 || *v > 9.0e15 {
                    return Err(anyhow!(
                        "spec: 'seed' must be a non-negative integer ≤ 2^53 \
                         (use a string for larger seeds)"
                    ));
                }
                spec.seed = *v as u64;
            }
            _ => return Err(anyhow!("spec: 'seed' must be an integer or string")),
        }
        match j.get("engine") {
            Json::Null => {}
            v => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow!("spec: 'engine' must be a string"))?;
                spec.engine =
                    Engine::parse(s).ok_or_else(|| anyhow!("spec: unknown engine '{s}'"))?;
            }
        }
        match j.get("kernel") {
            Json::Null => {}
            v => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow!("spec: 'kernel' must be a string"))?;
                spec.kernel = KernelChoice::parse(s)
                    .ok_or_else(|| anyhow!("spec: unknown kernel choice '{s}'"))?;
            }
        }
        Ok(spec)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    pub fn from_json_file(path: &std::path::Path) -> Result<OptimizerSpec> {
        Self::from_json(&Json::parse_file(path)?)
    }

    /// Write the replayable spec manifest (`pogo run --spec` input format).
    pub fn write_json_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json_string() + "\n")?;
        Ok(())
    }
}

/// Narrow a concrete-f32 engine to the requested scalar type. Succeeds
/// exactly when `S == f32` (checked via `TypeId`, no unsafe).
fn into_scalar_engine<S: crate::linalg::Scalar>(
    opt: Box<dyn Orthoptimizer<f32>>,
) -> Option<Box<dyn Orthoptimizer<S>>> {
    let any: Box<dyn std::any::Any> = Box::new(opt);
    any.downcast::<Box<dyn Orthoptimizer<S>>>().ok().map(|b| *b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifold::stiefel;
    use crate::rng::Rng;

    #[test]
    fn builds_every_rust_method() {
        let mut rng = Rng::seed_from_u64(0);
        for &m in Method::all() {
            let spec = OptimizerSpec::new(m, 0.05);
            let mut opt = spec.build(None, (1, 4, 8)).unwrap();
            let mut x = stiefel::random_point(4, 8, &mut rng);
            let g = crate::linalg::MatF::randn(4, 8, &mut rng);
            opt.step(0, &mut x, &g).unwrap();
            assert!(x.all_finite(), "{}", m.name());
        }
    }

    #[test]
    fn builds_generic_f64() {
        let mut rng = Rng::seed_from_u64(1);
        for &m in Method::all() {
            let spec = OptimizerSpec::new(m, 0.05);
            let mut opt = spec.build::<f64>(None, (1, 4, 8)).unwrap();
            let mut x = stiefel::random_point_t::<f64>(4, 8, &mut rng);
            let g = crate::linalg::MatD::randn(4, 8, &mut rng);
            opt.step(0, &mut x, &g).unwrap();
            assert!(x.all_finite(), "{}", m.name());
        }
    }

    #[test]
    fn batched_host_engine_builds_without_registry() {
        let mut rng = Rng::seed_from_u64(5);
        let spec = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(Engine::BatchedHost);
        assert_eq!(spec.label(), "POGO[batched]");
        let mut opt = spec.build::<f32>(None, (3, 4, 8)).unwrap();
        assert!(opt.prefers_batch());
        let mut xs: Vec<crate::linalg::MatF> =
            (0..3).map(|_| stiefel::random_point(4, 8, &mut rng)).collect();
        let gs: Vec<crate::linalg::MatF> =
            (0..3).map(|_| crate::linalg::MatF::randn(4, 8, &mut rng)).collect();
        opt.step_group(&mut xs, &gs).unwrap();
        for x in &xs {
            assert!(x.all_finite());
        }
        // Scalar-generic, like the host loop.
        assert!(spec.build::<f64>(None, (3, 4, 8)).is_ok());
        // Retraction methods have no batched engine.
        let rgd = OptimizerSpec::new(Method::Rgd, 0.05).with_engine(Engine::BatchedHost);
        assert!(rgd.build::<f32>(None, (3, 4, 8)).is_err());
    }

    #[test]
    fn unitary_engine_dispatch() {
        // Complex builds honour spec.engine: loop, batched, no-XLA.
        let spec = OptimizerSpec::new(Method::Pogo, 0.05);
        let loop_opt = spec.build_unitary::<f32>(4).unwrap();
        assert!(!loop_opt.prefers_batch());
        let batched = spec.with_engine(Engine::BatchedHost).build_unitary::<f32>(4).unwrap();
        assert!(batched.prefers_batch());
        assert!(batched.name().contains("[batched]"));
        assert!(spec.with_engine(Engine::Xla).build_unitary::<f32>(4).is_err());
        // Engine round-trips through JSON for the complex path too.
        let s = spec.with_engine(Engine::BatchedHost);
        let back = OptimizerSpec::from_json(&Json::parse(&s.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back, s);
        assert!(back.build_unitary::<f32>(2).unwrap().prefers_batch());
    }

    #[test]
    fn xla_engine_requires_registry() {
        let spec = OptimizerSpec::new(Method::Pogo, 0.1).with_engine(Engine::Xla);
        assert!(spec.build::<f32>(None, (1, 4, 8)).is_err());
    }

    #[test]
    fn rgd_has_no_xla_engine() {
        let spec = OptimizerSpec::new(Method::Rgd, 0.1).with_engine(Engine::Xla);
        // Even with a registry it must refuse (host retraction by design) —
        // error text differs depending on registry availability; both Err.
        assert!(spec.build::<f32>(None, (1, 4, 8)).is_err());
    }

    #[test]
    fn json_roundtrip_defaults() {
        let spec = OptimizerSpec::new(Method::Pogo, 0.1);
        let text = spec.to_json().to_string();
        let back = OptimizerSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(spec, back);
        assert_eq!(back.to_json().to_string(), text, "byte-identical reserialization");
    }

    #[test]
    fn minimal_spec_parses_with_defaults() {
        let j = Json::parse(r#"{"method": "rsdm", "lr": 0.5}"#).unwrap();
        let spec = OptimizerSpec::from_json(&j).unwrap();
        assert_eq!(spec.method, Method::Rsdm);
        assert_eq!(spec.submanifold_dim, 32);
        assert_eq!(spec.engine, Engine::Rust);
        assert_eq!(spec.kernel, KernelChoice::Auto);
        assert!(OptimizerSpec::from_json(&Json::parse(r#"{"lr": 1}"#).unwrap()).is_err());
    }

    #[test]
    fn kernel_choice_round_trips_in_spec_json() {
        for (k, name) in [
            (KernelChoice::Auto, "auto"),
            (KernelChoice::Fused, "fused"),
            (KernelChoice::Naive, "naive"),
        ] {
            let spec = OptimizerSpec::new(Method::Pogo, 0.1)
                .with_engine(Engine::BatchedHost)
                .with_kernel(k);
            let text = spec.to_json().to_string();
            assert!(text.contains(&format!("\"kernel\": \"{name}\"")), "{text}");
            let back = OptimizerSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, spec);
        }
        // Present-but-malformed is an error, like every other field.
        let bad = Json::parse(r#"{"method": "pogo", "lr": 0.1, "kernel": "simd"}"#).unwrap();
        assert!(OptimizerSpec::from_json(&bad).is_err());
    }
}
