//! Optimizer construction + engine dispatch.
//!
//! An [`OptimizerSpec`] is the serializable description of "which method,
//! which hyperparameters, which engine"; `build` turns it into a concrete
//! stepper for one shape group, choosing between the pure-Rust engine and
//! the XLA (AOT Pallas) engine.

use crate::optim::base::BaseOptKind;
use crate::optim::landing::{Landing, LandingConfig};
use crate::optim::pogo::{LambdaPolicy, Pogo, PogoConfig};
use crate::optim::rgd::{Rgd, RgdConfig};
use crate::optim::rsdm::{Rsdm, RsdmConfig};
use crate::optim::slpg::{Slpg, SlpgConfig};
use crate::optim::{adam, Engine, Method, Orthoptimizer};
use crate::runtime::stepper::{StepKind, XlaStepper};
use crate::runtime::Registry;
use anyhow::{anyhow, Result};

/// Full optimizer description (mirrors the paper's per-method knobs).
#[derive(Clone, Copy, Debug)]
pub struct OptimizerSpec {
    pub method: Method,
    pub lr: f64,
    pub base: BaseOptKind,
    /// POGO λ policy.
    pub lambda: LambdaPolicy,
    /// Landing/LandingPC attraction strength.
    pub attraction: f64,
    /// RSDM submanifold dimension.
    pub submanifold_dim: usize,
    pub seed: u64,
    pub engine: Engine,
}

impl OptimizerSpec {
    pub fn new(method: Method, lr: f64) -> Self {
        OptimizerSpec {
            method,
            lr,
            base: BaseOptKind::Sgd,
            lambda: LambdaPolicy::Half,
            attraction: 1.0,
            submanifold_dim: 32,
            seed: 0,
            engine: Engine::Rust,
        }
    }

    pub fn with_base(mut self, base: BaseOptKind) -> Self {
        self.base = base;
        self
    }

    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_lambda(mut self, lambda: LambdaPolicy) -> Self {
        self.lambda = lambda;
        self
    }

    pub fn with_attraction(mut self, a: f64) -> Self {
        self.attraction = a;
        self
    }

    pub fn with_submanifold(mut self, r: usize) -> Self {
        self.submanifold_dim = r;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Display label (method + engine) for figures.
    pub fn label(&self) -> String {
        let eng = match self.engine {
            Engine::Rust => "",
            Engine::Xla => "[xla]",
        };
        format!("{}{eng}", self.method.name())
    }

    /// Build a stepper for one `(group_size, p, n)` group.
    ///
    /// `registry` is required for `Engine::Xla`; the artifact for the
    /// group shape must exist (aot.py emits one per experiment shape).
    pub fn build(
        &self,
        registry: Option<&Registry>,
        group: (usize, usize, usize),
    ) -> Result<Box<dyn Orthoptimizer<f32>>> {
        let (b, p, n) = group;
        if self.engine == Engine::Xla {
            let reg = registry.ok_or_else(|| anyhow!("XLA engine needs a registry"))?;
            let kind = match (self.method, self.base, self.lambda) {
                (Method::Pogo, BaseOptKind::VAdam { .. }, LambdaPolicy::Half) => {
                    StepKind::PogoVadam
                }
                (Method::Pogo, _, LambdaPolicy::Half) => StepKind::Pogo,
                (Method::Pogo, _, LambdaPolicy::FindRoot) => StepKind::PogoFindRoot,
                (Method::Landing | Method::LandingPC, _, _) => StepKind::Landing,
                (Method::Slpg, _, _) => StepKind::Slpg,
                (m, _, _) => {
                    return Err(anyhow!("{} has no XLA engine (host retraction)", m.name()))
                }
            };
            let mut stepper = XlaStepper::new(reg, kind, self.lr, b, p, n)?;
            stepper.attraction = self.attraction;
            stepper.normalize_grad = self.method == Method::LandingPC;
            if self.method == Method::LandingPC {
                // LandingPC has no safeguard (paper §5.1); neutralize it.
                stepper.eps_ball = 1e9;
            }
            stepper.set_base(self.base);
            return Ok(Box::new(stepper));
        }
        Ok(match self.method {
            Method::Pogo => Box::new(Pogo::<f32>::new(
                PogoConfig { lr: self.lr, lambda: self.lambda, base: self.base },
                b,
            )),
            Method::Landing => Box::new(Landing::<f32>::new(
                LandingConfig {
                    lr: self.lr,
                    attraction: self.attraction,
                    base: self.base,
                    ..Default::default()
                },
                b,
            )),
            Method::LandingPC => Box::new(Landing::<f32>::new(
                LandingConfig::landing_pc(self.lr, self.attraction),
                b,
            )),
            Method::Slpg => {
                Box::new(Slpg::<f32>::new(SlpgConfig { lr: self.lr, base: self.base }, b))
            }
            Method::Rgd => {
                Box::new(Rgd::<f32>::new(RgdConfig { lr: self.lr, base: self.base }, b))
            }
            Method::Rsdm => Box::new(Rsdm::<f32>::new(
                RsdmConfig {
                    lr: self.lr,
                    submanifold_dim: self.submanifold_dim,
                    base: self.base,
                    seed: self.seed,
                    ..Default::default()
                },
                b,
            )),
            Method::Adam => Box::new(adam::Adam::<f32>::new(
                adam::AdamConfig { lr: self.lr, ..Default::default() },
                b,
            )),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifold::stiefel;
    use crate::rng::Rng;

    #[test]
    fn builds_every_rust_method() {
        let mut rng = Rng::seed_from_u64(0);
        for &m in Method::all() {
            let spec = OptimizerSpec::new(m, 0.05);
            let mut opt = spec.build(None, (1, 4, 8)).unwrap();
            let mut x = stiefel::random_point(4, 8, &mut rng);
            let g = crate::linalg::MatF::randn(4, 8, &mut rng);
            opt.step(0, &mut x, &g);
            assert!(x.all_finite(), "{}", m.name());
        }
    }

    #[test]
    fn xla_engine_requires_registry() {
        let spec = OptimizerSpec::new(Method::Pogo, 0.1).with_engine(Engine::Xla);
        assert!(spec.build(None, (1, 4, 8)).is_err());
    }

    #[test]
    fn rgd_has_no_xla_engine() {
        let spec = OptimizerSpec::new(Method::Rgd, 0.1).with_engine(Engine::Xla);
        // Even with a registry it must refuse (host retraction by design) —
        // error text differs depending on registry availability; both Err.
        assert!(spec.build(None, (1, 4, 8)).is_err());
    }
}
