//! The training loop: shape-grouped constrained updates + free-parameter
//! Adam + schedules + telemetry, behind one `Trainer::step` call.
//!
//! Gradients come from a [`GradSource`] — either closed-form Rust (Fig. 4),
//! or an AOT loss+grad executable (NN experiments). The trainer neither
//! knows nor cares: it routes per-parameter gradients to the right stepper
//! group and keeps the books (loss, feasibility, wall time, lr).

use super::engine::OptimizerSpec;
use super::metrics::MetricLog;
use super::param_store::{Group, ParamStore};
use super::scheduler::{EarlyStop, Scheduler};
use super::session::OptimSession;
use crate::linalg::MatF;
use crate::optim::adam::{Adam, AdamConfig};
use crate::optim::Orthoptimizer;
use crate::runtime::Registry;
use anyhow::Result;

/// Produces (loss, per-parameter gradients aligned with store indices).
pub trait GradSource {
    fn eval(&mut self, store: &ParamStore) -> Result<(f64, Vec<MatF>)>;
}

impl<F> GradSource for F
where
    F: FnMut(&ParamStore) -> Result<(f64, Vec<MatF>)>,
{
    fn eval(&mut self, store: &ParamStore) -> Result<(f64, Vec<MatF>)> {
        self(store)
    }
}

/// Trainer configuration.
#[derive(Debug)]
pub struct TrainerConfig {
    pub max_steps: usize,
    /// Record metrics every k steps (distance probes cost O(p²n)).
    pub log_every: usize,
    /// Optional lr schedule observing the loss.
    pub scheduler: Option<Scheduler>,
    /// Optional early stopping observing the loss.
    pub early_stop: Option<EarlyStop>,
    /// Stop when the loss (or externally-set monitor) reaches this value.
    pub target_loss: Option<f64>,
    /// Learning rate for free (unconstrained) parameters.
    pub free_lr: f64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            max_steps: 1000,
            log_every: 10,
            scheduler: None,
            early_stop: None,
            target_loss: None,
            free_lr: 1e-3,
        }
    }
}

/// The coordinator's training engine for one run.
pub struct Trainer {
    pub store: ParamStore,
    pub cfg: TrainerConfig,
    pub log: MetricLog,
    session: OptimSession,
    free_opt: Adam<f32>,
    free_indices: Vec<usize>,
    step_idx: usize,
}

impl Trainer {
    /// Build a trainer: an [`OptimSession`] (one stepper per shape group)
    /// per the spec, plus Adam for the free parameters.
    pub fn new(
        store: ParamStore,
        spec: OptimizerSpec,
        registry: Option<&Registry>,
        cfg: TrainerConfig,
    ) -> Result<Trainer> {
        let session = OptimSession::new(&spec, &store, registry)?;
        Ok(Self::with_session(store, session, cfg))
    }

    /// Build a trainer around a pre-assembled session (custom engines,
    /// tests).
    pub fn with_session(store: ParamStore, session: OptimSession, cfg: TrainerConfig) -> Trainer {
        let free_indices = store.free_indices();
        let free_opt =
            Adam::new(AdamConfig { lr: cfg.free_lr, ..Default::default() }, store.len());
        let label = session.label().to_string();
        Trainer {
            store,
            cfg,
            log: MetricLog::new(label),
            session,
            free_opt,
            free_indices,
            step_idx: 0,
        }
    }

    pub fn groups(&self) -> &[Group] {
        self.session.groups()
    }

    /// The constrained-update session (per-shape-group steppers).
    pub fn session(&self) -> &OptimSession {
        &self.session
    }

    pub fn session_mut(&mut self) -> &mut OptimSession {
        &mut self.session
    }

    pub fn step_idx(&self) -> usize {
        self.step_idx
    }

    /// Set the constrained-optimizer learning rate (all groups).
    pub fn set_lr(&mut self, lr: f64) {
        self.session.set_lr(lr);
    }

    pub fn lr(&self) -> f64 {
        self.session.lr()
    }

    /// One optimization step given gradients from `src`.
    /// Returns the loss. Engine errors propagate instead of panicking.
    pub fn step(&mut self, src: &mut dyn GradSource) -> Result<f64> {
        let (loss, grads) = src.eval(&self.store)?;
        debug_assert_eq!(grads.len(), self.store.len(), "one gradient per parameter");

        // Constrained groups: batched dispatch via the session.
        self.session.apply(&mut self.store, &grads)?;
        // Free parameters: Adam.
        for &i in &self.free_indices.clone() {
            let mat = &mut self.store.get_mut(i).mat;
            // Split borrow: Adam state indexed by param id.
            let mut m = std::mem::replace(mat, MatF::zeros(1, 1));
            self.free_opt.step(i, &mut m, &grads[i])?;
            self.store.get_mut(i).mat = m;
        }

        self.step_idx += 1;
        // Schedules observe the loss.
        if let Some(s) = &mut self.cfg.scheduler {
            let lr = s.observe(loss);
            self.session.set_lr(lr);
        }
        Ok(loss)
    }

    /// Record standard telemetry (loss, feasibility, lr) at this step.
    pub fn record(&mut self, loss: f64, extra: &[(&str, f64)]) {
        let dist = self.store.max_stiefel_distance();
        let ndist = self.store.max_normalized_distance();
        let mut vals: Vec<(&str, f64)> = vec![
            ("loss", loss),
            ("distance", dist),
            ("norm_distance", ndist),
            ("lr", self.lr()),
        ];
        vals.extend_from_slice(extra);
        self.log.record(self.step_idx, &vals);
    }

    /// Run up to `cfg.max_steps` steps, recording every `log_every`.
    /// Returns the final loss. Stops early on target/early-stop signals.
    pub fn run(&mut self, src: &mut dyn GradSource) -> Result<f64> {
        let mut last = f64::NAN;
        for _ in 0..self.cfg.max_steps {
            let loss = self.step(src)?;
            last = loss;
            if self.step_idx % self.cfg.log_every == 0 || self.step_idx == 1 {
                self.record(loss, &[]);
            }
            if let Some(t) = self.cfg.target_loss {
                if loss <= t {
                    self.record(loss, &[]);
                    log::info!("target loss {t} reached at step {}", self.step_idx);
                    break;
                }
            }
            if let Some(es) = &mut self.cfg.early_stop {
                if es.observe(loss) {
                    self.record(loss, &[]);
                    log::info!("early stop at step {}", self.step_idx);
                    break;
                }
            }
        }
        Ok(last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_at_b};
    use crate::optim::Method;
    use crate::rng::Rng;

    /// Multi-matrix Procrustes: each group member has its own target.
    struct MultiProcrustes {
        a: Vec<MatF>,
        b: Vec<MatF>,
    }

    impl GradSource for MultiProcrustes {
        fn eval(&mut self, store: &ParamStore) -> Result<(f64, Vec<MatF>)> {
            let mut loss = 0.0;
            let mut grads = Vec::with_capacity(store.len());
            for (i, p) in store.params().iter().enumerate() {
                let r = matmul(&self.a[i], &p.mat).sub(&self.b[i]);
                loss += r.norm_sq() as f64;
                grads.push(matmul_at_b(&self.a[i], &r).scale(2.0));
            }
            Ok((loss, grads))
        }
    }

    fn setup(n_mats: usize, p: usize, n: usize, seed: u64) -> (ParamStore, MultiProcrustes) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut store = ParamStore::new();
        store.add_stiefel_group("x", n_mats, p, n, &mut rng);
        let a: Vec<MatF> = (0..n_mats).map(|_| MatF::randn(p, p, &mut rng)).collect();
        let b: Vec<MatF> = (0..n_mats).map(|_| MatF::randn(p, n, &mut rng)).collect();
        (store, MultiProcrustes { a, b })
    }

    #[test]
    fn trains_multi_matrix_group() {
        let (store, mut src) = setup(6, 5, 10, 0);
        let spec = OptimizerSpec::new(Method::Pogo, 0.02);
        let mut tr = Trainer::new(
            store,
            spec,
            None,
            TrainerConfig { max_steps: 150, log_every: 25, ..Default::default() },
        )
        .unwrap();
        let l0 = src.eval(&tr.store).unwrap().0;
        let l1 = tr.run(&mut src).unwrap();
        assert!(l1 < l0 * 0.8, "{l0} → {l1}");
        assert!(tr.store.max_stiefel_distance() < 1e-3);
        assert!(!tr.log.is_empty());
    }

    #[test]
    fn target_loss_stops_early() {
        let (store, mut src) = setup(2, 4, 8, 1);
        let spec = OptimizerSpec::new(Method::Pogo, 0.05);
        let l0 = src.eval(&store).unwrap().0;
        let mut tr = Trainer::new(
            store,
            spec,
            None,
            TrainerConfig {
                max_steps: 10_000,
                target_loss: Some(l0 * 0.9),
                log_every: 1000,
                ..Default::default()
            },
        )
        .unwrap();
        tr.run(&mut src).unwrap();
        assert!(tr.step_idx() < 10_000, "should stop well before max_steps");
    }

    #[test]
    fn scheduler_reduces_lr() {
        let (store, mut src) = setup(1, 4, 8, 2);
        let spec = OptimizerSpec::new(Method::Pogo, 0.1);
        let mut tr = Trainer::new(
            store,
            spec,
            None,
            TrainerConfig {
                max_steps: 50,
                scheduler: Some(Scheduler::new(
                    crate::coordinator::scheduler::LrSchedule::Step { every: 10, gamma: 0.5 },
                    0.1,
                )),
                ..Default::default()
            },
        )
        .unwrap();
        tr.run(&mut src).unwrap();
        assert!(tr.lr() < 0.1 * 0.5 + 1e-12);
    }

    #[test]
    fn free_params_update_via_adam() {
        // One free matrix chasing a target; no constrained params.
        let mut store = ParamStore::new();
        let target = MatF::ones(3, 3);
        store.add_free("w", MatF::zeros(3, 3));
        let spec = OptimizerSpec::new(Method::Pogo, 0.1);
        let mut tr = Trainer::new(
            store,
            spec,
            None,
            TrainerConfig { max_steps: 300, free_lr: 0.05, ..Default::default() },
        )
        .unwrap();
        let t2 = target.clone();
        let mut src = move |store: &ParamStore| {
            let w = store.mat(0);
            let r = w.sub(&t2);
            Ok(((r.norm_sq()) as f64, vec![r.scale(2.0)]))
        };
        tr.run(&mut src).unwrap();
        assert!(tr.store.mat(0).sub(&target).norm() < 0.2);
    }
}
