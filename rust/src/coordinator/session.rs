//! OptimSession: the per-shape-group stepper set behind one handle.
//!
//! A session owns one [`Orthoptimizer`] per constrained shape group of a
//! [`ParamStore`] and runs the whole extract → batched-step → write-back
//! loop in [`OptimSession::apply`]. The [`Trainer`](super::Trainer) is a
//! thin client of this type, and experiment drivers that don't need the
//! Trainer's schedules/telemetry (scale sweeps, custom loops) can drive a
//! session directly instead of re-implementing the group loop.
//!
//! Field-generic: `OptimSession<f32>` (the default) steps real Stiefel
//! stores; [`OptimSession::new_unitary`] builds a
//! `OptimSession<Complex<S>>` over a complex store, sharing the same
//! `apply` loop — including the packed-`BatchMat` fast path for the
//! batched unitary engine (Fig. 8's thousands-of-cores regime).

use super::engine::OptimizerSpec;
use super::param_store::{Group, ParamStore};
use crate::linalg::{BatchMat, Complex, Field, Mat, Scalar};
use crate::optim::Orthoptimizer;
use crate::runtime::Registry;
use anyhow::{ensure, Context, Result};

/// Per-shape-group steppers for one run, built from a single
/// [`OptimizerSpec`] (the crate's one construction path).
pub struct OptimSession<E: Field = f32> {
    label: String,
    groups: Vec<Group>,
    steppers: Vec<Box<dyn Orthoptimizer<E>>>,
}

impl OptimSession<f32> {
    /// Build one stepper per constrained shape group of `store` (real
    /// Stiefel, f32 — the experiment default).
    ///
    /// `registry` is required when `spec.engine == Engine::Xla`.
    pub fn new(
        spec: &OptimizerSpec,
        store: &ParamStore<f32>,
        registry: Option<&Registry>,
    ) -> Result<OptimSession<f32>> {
        let groups = store.stiefel_groups();
        let mut steppers = Vec::with_capacity(groups.len());
        for g in &groups {
            let (p, n) = g.shape;
            let stepper = spec
                .build::<f32>(registry, (g.indices.len(), p, n))
                .with_context(|| {
                    format!("building {} for group ({p}, {n})×{}", spec.label(), g.indices.len())
                })?;
            steppers.push(stepper);
        }
        Ok(OptimSession { label: spec.label(), groups, steppers })
    }
}

impl<S: Scalar> OptimSession<Complex<S>> {
    /// Build one unitary stepper per constrained shape group of a complex
    /// store. Engine dispatch mirrors the real path: `rust` is the
    /// per-matrix loop, `batched-host` packs each group into one
    /// `(B, p, n)` complex tensor; `xla` is rejected (the tiny Born cores
    /// make complex XLA dispatch overhead-bound — see
    /// `OptimizerSpec::build_unitary`).
    pub fn new_unitary(
        spec: &OptimizerSpec,
        store: &ParamStore<Complex<S>>,
    ) -> Result<OptimSession<Complex<S>>> {
        let groups = store.stiefel_groups();
        let mut steppers = Vec::with_capacity(groups.len());
        for g in &groups {
            let (p, n) = g.shape;
            let stepper = spec.build_unitary::<S>(g.indices.len()).with_context(|| {
                format!(
                    "building unitary {} for group ({p}, {n})×{}",
                    spec.label(),
                    g.indices.len()
                )
            })?;
            steppers.push(stepper);
        }
        Ok(OptimSession { label: spec.label(), groups, steppers })
    }
}

impl<E: Field> OptimSession<E> {
    /// Assemble a session from pre-built steppers (custom engines, tests).
    /// `steppers[i]` updates `groups[i]`.
    pub fn from_parts(
        label: impl Into<String>,
        groups: Vec<Group>,
        steppers: Vec<Box<dyn Orthoptimizer<E>>>,
    ) -> Result<OptimSession<E>> {
        ensure!(
            groups.len() == steppers.len(),
            "{} groups vs {} steppers",
            groups.len(),
            steppers.len()
        );
        Ok(OptimSession { label: label.into(), groups, steppers })
    }

    /// Display label of the underlying spec (method + engine).
    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn groups(&self) -> &[Group] {
        &self.groups
    }

    pub fn steppers(&self) -> &[Box<dyn Orthoptimizer<E>>] {
        &self.steppers
    }

    /// Set the constrained-optimizer learning rate (all groups).
    pub fn set_lr(&mut self, lr: f64) {
        for s in &mut self.steppers {
            s.set_lr(lr);
        }
    }

    pub fn lr(&self) -> f64 {
        self.steppers.first().map(|s| s.lr()).unwrap_or(0.0)
    }

    /// One constrained update over every group: extract the group's
    /// matrices, dispatch one batched step, write the results back.
    /// `grads` is indexed by store parameter index (free-parameter slots
    /// are ignored). Errors from any group's engine propagate.
    ///
    /// Engines whose native unit of work is a packed tensor
    /// (`prefers_batch()`, e.g. `Engine::BatchedHost` — real or complex)
    /// get the whole group as ONE `(B, p, n)` [`BatchMat`] — no
    /// per-matrix clones on either side of the step. Everything else
    /// keeps the per-matrix `step_group` path.
    pub fn apply(&mut self, store: &mut ParamStore<E>, grads: &[Mat<E>]) -> Result<()> {
        let t0 = crate::obs::enabled().then(std::time::Instant::now);
        for (g, stepper) in self.groups.iter().zip(&mut self.steppers) {
            let ctx = || {
                format!(
                    "stepping group ({}, {}){}",
                    g.shape.0,
                    g.shape.1,
                    if g.key.is_empty() { String::new() } else { format!(" '{}'", g.key) }
                )
            };
            if stepper.prefers_batch() {
                let mut xb = store.extract_group_batch(g);
                let (p, n) = g.shape;
                let mut gb = BatchMat::<E>::zeros(g.indices.len(), p, n);
                for (bi, &i) in g.indices.iter().enumerate() {
                    gb.set_mat(bi, &grads[i]);
                }
                stepper.step_batch(&mut xb, &gb).with_context(ctx)?;
                store.write_group_batch(g, &xb);
            } else {
                let mut xs = store.extract_group(g);
                let gs: Vec<Mat<E>> = g.indices.iter().map(|&i| grads[i].clone()).collect();
                stepper.step_group(&mut xs, &gs).with_context(ctx)?;
                store.write_group(g, xs);
            }
        }
        if let Some(t0) = t0 {
            crate::obs::hist::SESSION_APPLY_SECONDS.hist0().record_since(t0);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CMatF, Mat, MatF};
    use crate::manifold::stiefel;
    use crate::optim::{Engine, Method};
    use crate::rng::Rng;
    use anyhow::anyhow;

    #[test]
    fn applies_batched_updates_per_group() {
        let mut rng = Rng::seed_from_u64(0);
        let mut store = ParamStore::new();
        store.add_stiefel_group("a", 3, 4, 8, &mut rng);
        store.add_stiefel_group("b", 2, 3, 6, &mut rng);
        store.add_free("head", MatF::zeros(2, 2));
        let spec = OptimizerSpec::new(Method::Pogo, 0.05);
        let mut session = OptimSession::new(&spec, &store, None).unwrap();
        assert_eq!(session.groups().len(), 2);
        let grads: Vec<MatF> = store
            .params()
            .iter()
            .map(|p| MatF::randn(p.mat.rows(), p.mat.cols(), &mut rng))
            .collect();
        let before: Vec<MatF> = (0..store.len()).map(|i| store.mat(i).clone()).collect();
        session.apply(&mut store, &grads).unwrap();
        // Constrained params moved and stayed feasible; free param untouched.
        for i in 0..5 {
            assert!(store.mat(i).sub(&before[i]).norm() > 0.0, "param {i} unchanged");
            assert!(stiefel::distance(store.mat(i)) < 1e-3);
        }
        assert_eq!(store.mat(5), &before[5]);
    }

    #[test]
    fn lr_fans_out_to_all_steppers() {
        let mut rng = Rng::seed_from_u64(1);
        let mut store = ParamStore::new();
        store.add_stiefel_group("a", 2, 3, 6, &mut rng);
        store.add_stiefel_group("b", 2, 4, 8, &mut rng);
        let spec = OptimizerSpec::new(Method::Landing, 0.2);
        let mut session = OptimSession::new(&spec, &store, None).unwrap();
        session.set_lr(0.01);
        assert_eq!(session.lr(), 0.01);
        for s in session.steppers() {
            assert_eq!(s.lr(), 0.01);
        }
    }

    #[test]
    fn batched_engine_session_matches_loop_engine() {
        let mut rng = Rng::seed_from_u64(9);
        let mut store_loop = ParamStore::new();
        store_loop.add_stiefel_group("k", 6, 3, 3, &mut rng);
        store_loop.add_stiefel_group("w", 2, 4, 8, &mut rng);
        let store_batched = store_loop.clone();
        let grads: Vec<MatF> = store_loop
            .params()
            .iter()
            .map(|p| MatF::randn(p.mat.rows(), p.mat.cols(), &mut rng).scale(0.1))
            .collect();

        let spec = OptimizerSpec::new(Method::Pogo, 0.05);
        let mut s_loop = OptimSession::new(&spec, &store_loop, None).unwrap();
        let mut s_batched =
            OptimSession::new(&spec.with_engine(Engine::BatchedHost), &store_batched, None)
                .unwrap();
        assert!(s_batched.steppers().iter().all(|s| s.prefers_batch()));

        let mut store_batched = store_batched;
        for _ in 0..3 {
            s_loop.apply(&mut store_loop, &grads).unwrap();
            s_batched.apply(&mut store_batched, &grads).unwrap();
        }
        for i in 0..store_loop.len() {
            let d = store_loop.mat(i).sub(store_batched.mat(i)).max_abs();
            assert!(d <= 1e-6, "param {i} diverged by {d}");
        }
    }

    #[test]
    fn unitary_session_batched_matches_loop() {
        // The complex plumbing end-to-end: a unitary store stepped through
        // OptimSession under both engines must agree elementwise — the
        // batched path extracts ONE packed complex tensor per group.
        use crate::linalg::Complex;
        let mut rng = Rng::seed_from_u64(11);
        let mut store_loop: ParamStore<Complex<f32>> = ParamStore::new();
        store_loop.add_unitary_group("cores", 5, 4, 8, &mut rng);
        store_loop.add_unitary_group("small", 3, 2, 2, &mut rng);
        let mut store_batched = store_loop.clone();

        let spec = OptimizerSpec::new(Method::Pogo, 0.05);
        let mut s_loop = OptimSession::new_unitary(&spec, &store_loop).unwrap();
        let mut s_batched = OptimSession::new_unitary(
            &spec.with_engine(Engine::BatchedHost),
            &store_batched,
        )
        .unwrap();
        assert!(s_batched.steppers().iter().all(|s| s.prefers_batch()));
        assert!(s_loop.steppers().iter().all(|s| !s.prefers_batch()));

        for step in 0..3u64 {
            let mut rng = Rng::seed_from_u64(100 + step);
            let grads: Vec<CMatF> = store_loop
                .params()
                .iter()
                .map(|p| {
                    let g = CMatF::randn(p.mat.rows(), p.mat.cols(), &mut rng);
                    let n = g.norm();
                    g.scale(Complex::from_f64(0.2 / n as f64))
                })
                .collect();
            s_loop.apply(&mut store_loop, &grads).unwrap();
            s_batched.apply(&mut store_batched, &grads).unwrap();
        }
        for i in 0..store_loop.len() {
            let d = store_loop.mat(i).sub(store_batched.mat(i)).norm();
            assert!(d <= 1e-5, "param {i} diverged by {d}");
        }
        assert!(store_batched.max_stiefel_distance() < 1e-3);
    }

    /// A stepper whose engine always fails — exercises error propagation
    /// through the group loop without needing a broken XLA artifact.
    struct FailingStepper;

    impl Orthoptimizer<f32> for FailingStepper {
        fn step(&mut self, _idx: usize, _x: &mut Mat<f32>, _g: &Mat<f32>) -> Result<()> {
            Err(anyhow!("engine exploded"))
        }
        fn name(&self) -> &str {
            "failing"
        }
        fn lr(&self) -> f64 {
            0.0
        }
        fn set_lr(&mut self, _lr: f64) {}
    }

    #[test]
    fn engine_errors_propagate_with_group_context() {
        let mut rng = Rng::seed_from_u64(2);
        let mut store = ParamStore::new();
        store.add_stiefel_group("g", 2, 4, 8, &mut rng);
        let groups = store.stiefel_groups();
        let mut session =
            OptimSession::from_parts("failing", groups, vec![Box::new(FailingStepper)])
                .unwrap();
        let grads: Vec<MatF> = (0..store.len()).map(|_| MatF::zeros(4, 8)).collect();
        let err = session.apply(&mut store, &grads).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("engine exploded"), "{text}");
        assert!(text.contains("stepping group"), "{text}");
    }

    #[test]
    fn from_parts_checks_arity() {
        assert!(OptimSession::from_parts("x", Vec::new(), vec![Box::new(FailingStepper)])
            .is_err());
    }
}
