//! Checkpointing: save/restore a `ParamStore` (and optimizer step count)
//! to disk, so long training runs survive restarts — table stakes for a
//! deployable trainer.
//!
//! Format: a small JSON header (names, shapes, constraints, keys, step)
//! followed by one raw little-endian f32 blob per parameter, all in a
//! single file. The header carries a blob checksum so truncated/corrupt
//! checkpoints are rejected rather than silently loaded.

use super::param_store::{Constraint, ParamStore};
use crate::linalg::MatF;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &str = "POGO-CKPT-v1";

/// FNV-1a over the raw bytes (cheap integrity check, not cryptographic).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Save the store (+ step counter) to `path`.
pub fn save(store: &ParamStore, step: usize, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    // Blob: all parameters' f32 data, in registration order.
    let mut blob: Vec<u8> = Vec::new();
    for p in store.params() {
        for &v in p.mat.as_slice() {
            blob.extend_from_slice(&v.to_le_bytes());
        }
    }
    let header = Json::obj(vec![
        ("magic", Json::str(MAGIC)),
        ("step", Json::num(step as f64)),
        ("checksum", Json::str(format!("{:016x}", fnv1a(&blob)))),
        (
            "params",
            Json::arr(store.params().iter().map(|p| {
                Json::obj(vec![
                    ("name", Json::str(p.name.clone())),
                    ("rows", Json::num(p.mat.rows() as f64)),
                    ("cols", Json::num(p.mat.cols() as f64)),
                    (
                        "constraint",
                        Json::str(match p.constraint {
                            Constraint::Stiefel => "stiefel",
                            Constraint::Free => "free",
                        }),
                    ),
                    ("key", Json::str(p.group_key.clone())),
                ])
            })),
        ),
    ]);
    let header_text = header.to_string();
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    // Layout: u32 header length, header bytes, blob.
    f.write_all(&(header_text.len() as u32).to_le_bytes())?;
    f.write_all(header_text.as_bytes())?;
    f.write_all(&blob)?;
    Ok(())
}

/// Load a checkpoint; returns (store, step).
pub fn load(path: &Path) -> Result<(ParamStore, usize)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut len_buf = [0u8; 4];
    f.read_exact(&mut len_buf)?;
    let hlen = u32::from_le_bytes(len_buf) as usize;
    let mut header_bytes = vec![0u8; hlen];
    f.read_exact(&mut header_bytes)?;
    let header = Json::parse(std::str::from_utf8(&header_bytes)?)
        .map_err(|e| anyhow!("corrupt checkpoint header: {e}"))?;
    if header.get("magic").as_str() != Some(MAGIC) {
        return Err(anyhow!("not a POGO checkpoint (bad magic)"));
    }
    let step = header.get("step").as_usize().unwrap_or(0);
    let mut blob = Vec::new();
    f.read_to_end(&mut blob)?;
    let want_sum = header.get("checksum").as_str().unwrap_or("");
    let got_sum = format!("{:016x}", fnv1a(&blob));
    if want_sum != got_sum {
        return Err(anyhow!("checkpoint checksum mismatch ({want_sum} vs {got_sum})"));
    }

    let mut store = ParamStore::new();
    let mut off = 0usize;
    for p in header.get("params").as_arr().unwrap_or(&[]) {
        let name = p.get("name").as_str().unwrap_or("").to_string();
        let rows = p.get("rows").as_usize().ok_or_else(|| anyhow!("bad rows"))?;
        let cols = p.get("cols").as_usize().ok_or_else(|| anyhow!("bad cols"))?;
        let n = rows * cols;
        let end = off + 4 * n;
        if end > blob.len() {
            return Err(anyhow!("checkpoint blob too short for '{name}'"));
        }
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            let b = &blob[off + 4 * i..off + 4 * i + 4];
            data.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        off = end;
        let mat = MatF::from_vec(rows, cols, data);
        match p.get("constraint").as_str() {
            Some("stiefel") => {
                let key = p.get("key").as_str().unwrap_or("").to_string();
                store.add_stiefel_keyed(name, mat, key);
            }
            _ => {
                store.add_free(name, mat);
            }
        }
    }
    if off != blob.len() {
        return Err(anyhow!("trailing bytes in checkpoint blob"));
    }
    Ok((store, step))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifold::stiefel;
    use crate::rng::Rng;

    fn sample_store() -> ParamStore {
        let mut rng = Rng::seed_from_u64(0);
        let mut store = ParamStore::new();
        store.add_stiefel_group("w", 3, 4, 8, &mut rng);
        store.add_free("head", MatF::randn(5, 2, &mut rng));
        store.add_stiefel_keyed("x", stiefel::random_point(2, 6, &mut rng), "solo");
        store
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pogo_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let store = sample_store();
        let path = tmp("roundtrip");
        save(&store, 1234, &path).unwrap();
        let (back, step) = load(&path).unwrap();
        assert_eq!(step, 1234);
        assert_eq!(back.len(), store.len());
        for (a, b) in store.params().iter().zip(back.params()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.constraint, b.constraint);
            assert_eq!(a.group_key, b.group_key);
            assert_eq!(a.mat, b.mat, "bit-exact restore for {}", a.name);
        }
        // Grouping structure survives.
        assert_eq!(back.stiefel_groups().len(), store.stiefel_groups().len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_blob_rejected() {
        let store = sample_store();
        let path = tmp("corrupt");
        save(&store, 1, &path).unwrap();
        // Flip a byte near the end.
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_rejected() {
        let store = sample_store();
        let path = tmp("trunc");
        save(&store, 1, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let path = tmp("magic");
        std::fs::write(&path, b"\x10\x00\x00\x00{\"magic\":\"nope\"}  ").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
