//! Checkpointing: save/restore a `ParamStore` (and optimizer step count)
//! to disk, so long training runs — and the serve daemon's resumable jobs
//! — survive restarts.
//!
//! Format (`POGO-CKPT-v1`): a small JSON header (dtype, names, shapes,
//! constraints, keys, step) followed by one raw little-endian scalar blob
//! per parameter, all in a single file. The header carries a blob checksum
//! so truncated/corrupt checkpoints are rejected rather than silently
//! loaded, and a `dtype` tag (`f32`/`f64`, or `c64`/`c128` for complex
//! stores serialized as interleaved re,im pairs) so a store is never
//! silently reinterpreted at the wrong precision or field: [`load_t`]
//! refuses a dtype mismatch with a clear error. Headers written before
//! the tag existed carry implicit `f32` (the only dtype v1 ever stored).

use super::param_store::{Constraint, ParamStore};
use crate::linalg::{Complex, Field, Mat};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &str = "POGO-CKPT-v1";

/// A matrix element the checkpoint format can store: adds the on-disk
/// dtype tag and little-endian (de)serialization to [`Field`]. Real
/// scalars store one word per element; complex elements store an
/// interleaved `re,im` pair (so `Fig. 8`-style unitary jobs resume too).
pub trait CkptDtype: Field {
    /// Header tag (`"f32"` / `"f64"` / `"c64"` / `"c128"`).
    const DTYPE: &'static str;
    /// Bytes per element on disk.
    const BYTES: usize;
    fn write_le(self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

impl CkptDtype for f32 {
    const DTYPE: &'static str = "f32";
    const BYTES: usize = 4;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl CkptDtype for f64 {
    const DTYPE: &'static str = "f64";
    const BYTES: usize = 8;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        f64::from_le_bytes([
            bytes[0], bytes[1], bytes[2], bytes[3], bytes[4], bytes[5], bytes[6], bytes[7],
        ])
    }
}

/// Complex elements serialize as an interleaved `re,im` pair of their
/// real dtype ("c64" = two f32 words, "c128" = two f64 words).
impl CkptDtype for Complex<f32> {
    const DTYPE: &'static str = "c64";
    const BYTES: usize = 8;
    fn write_le(self, out: &mut Vec<u8>) {
        self.re.write_le(out);
        self.im.write_le(out);
    }
    fn read_le(bytes: &[u8]) -> Self {
        Complex::new(f32::read_le(&bytes[..4]), f32::read_le(&bytes[4..8]))
    }
}

impl CkptDtype for Complex<f64> {
    const DTYPE: &'static str = "c128";
    const BYTES: usize = 16;
    fn write_le(self, out: &mut Vec<u8>) {
        self.re.write_le(out);
        self.im.write_le(out);
    }
    fn read_le(bytes: &[u8]) -> Self {
        Complex::new(f64::read_le(&bytes[..8]), f64::read_le(&bytes[8..16]))
    }
}

/// FNV-1a over the raw bytes (cheap integrity check, not cryptographic).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Save an f32 store (+ step counter) to `path` — the experiment default.
pub fn save(store: &ParamStore, step: usize, path: &Path) -> Result<()> {
    save_t::<f32>(store, step, path)
}

/// Load an f32 checkpoint; returns (store, step).
pub fn load(path: &Path) -> Result<(ParamStore, usize)> {
    load_t::<f32>(path)
}

/// Save a store (+ step counter) at any checkpointable dtype.
pub fn save_t<S: CkptDtype>(store: &ParamStore<S>, step: usize, path: &Path) -> Result<()> {
    let t0 = crate::obs::enabled().then(std::time::Instant::now);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    // Blob: all parameters' scalar data, in registration order.
    let mut blob: Vec<u8> = Vec::new();
    for p in store.params() {
        for &v in p.mat.as_slice() {
            v.write_le(&mut blob);
        }
    }
    let header = Json::obj(vec![
        ("magic", Json::str(MAGIC)),
        ("dtype", Json::str(S::DTYPE)),
        ("step", Json::num(step as f64)),
        ("checksum", Json::str(format!("{:016x}", fnv1a(&blob)))),
        (
            "params",
            Json::arr(store.params().iter().map(|p| {
                Json::obj(vec![
                    ("name", Json::str(p.name.clone())),
                    ("rows", Json::num(p.mat.rows() as f64)),
                    ("cols", Json::num(p.mat.cols() as f64)),
                    (
                        "constraint",
                        Json::str(match p.constraint {
                            Constraint::Stiefel => "stiefel",
                            Constraint::Free => "free",
                        }),
                    ),
                    ("key", Json::str(p.group_key.clone())),
                ])
            })),
        ),
    ]);
    let header_text = header.to_string();
    // Write-then-rename so a crash mid-save never destroys the previous
    // good checkpoint (the serve daemon's resume path depends on this).
    let tmp = path.with_file_name(format!(
        "{}.tmp",
        path.file_name().and_then(|n| n.to_str()).unwrap_or("ckpt")
    ));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        // Layout: u32 header length, header bytes, blob.
        f.write_all(&(header_text.len() as u32).to_le_bytes())?;
        f.write_all(header_text.as_bytes())?;
        f.write_all(&blob)?;
        f.sync_all().ok();
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    if let Some(t0) = t0 {
        crate::obs::hist::CHECKPOINT_IO_SECONDS.hist(&["save"]).record_since(t0);
    }
    Ok(())
}

/// Load a checkpoint at dtype `S`; returns (store, step). A checkpoint
/// written at a different dtype is rejected (convert explicitly via
/// `Mat::cast` after loading at the stored dtype — never reinterpreted).
pub fn load_t<S: CkptDtype>(path: &Path) -> Result<(ParamStore<S>, usize)> {
    let t0 = crate::obs::enabled().then(std::time::Instant::now);
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut len_buf = [0u8; 4];
    f.read_exact(&mut len_buf)
        .with_context(|| format!("reading header length of {}", path.display()))?;
    let hlen = u32::from_le_bytes(len_buf) as usize;
    if hlen > 16 << 20 {
        return Err(anyhow!("implausible checkpoint header length {hlen} (corrupt file?)"));
    }
    let mut header_bytes = vec![0u8; hlen];
    f.read_exact(&mut header_bytes)
        .with_context(|| format!("reading {hlen}-byte header of {}", path.display()))?;
    let header = Json::parse(std::str::from_utf8(&header_bytes)?)
        .map_err(|e| anyhow!("corrupt checkpoint header: {e}"))?;
    if header.get("magic").as_str() != Some(MAGIC) {
        return Err(anyhow!("not a POGO checkpoint (bad magic)"));
    }
    // Headers written before the dtype tag existed are implicitly f32.
    let dtype = header.get("dtype").as_str().unwrap_or("f32");
    if dtype != S::DTYPE {
        return Err(anyhow!(
            "checkpoint dtype is {dtype} but the load requested {} — refusing to \
             reinterpret; load at the stored dtype and cast explicitly",
            S::DTYPE
        ));
    }
    let step = header.get("step").as_usize().unwrap_or(0);
    let mut blob = Vec::new();
    f.read_to_end(&mut blob)?;
    let want_sum = header.get("checksum").as_str().unwrap_or("");
    let got_sum = format!("{:016x}", fnv1a(&blob));
    if want_sum != got_sum {
        return Err(anyhow!("checkpoint checksum mismatch ({want_sum} vs {got_sum})"));
    }

    let mut store = ParamStore::new();
    let mut off = 0usize;
    for p in header.get("params").as_arr().unwrap_or(&[]) {
        let name = p.get("name").as_str().unwrap_or("").to_string();
        let rows = p.get("rows").as_usize().ok_or_else(|| anyhow!("bad rows"))?;
        let cols = p.get("cols").as_usize().ok_or_else(|| anyhow!("bad cols"))?;
        let n = rows * cols;
        let end = off + S::BYTES * n;
        if end > blob.len() {
            return Err(anyhow!("checkpoint blob too short for '{name}'"));
        }
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            data.push(S::read_le(&blob[off + S::BYTES * i..off + S::BYTES * (i + 1)]));
        }
        off = end;
        let mat = Mat::<S>::from_vec(rows, cols, data);
        match p.get("constraint").as_str() {
            Some("stiefel") => {
                let key = p.get("key").as_str().unwrap_or("").to_string();
                store.add_stiefel_keyed(name, mat, key);
            }
            _ => {
                store.add_free(name, mat);
            }
        }
    }
    if off != blob.len() {
        return Err(anyhow!("trailing bytes in checkpoint blob"));
    }
    if let Some(t0) = t0 {
        crate::obs::hist::CHECKPOINT_IO_SECONDS.hist(&["restore"]).record_since(t0);
    }
    Ok((store, step))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{MatD, MatF};
    use crate::manifold::stiefel;
    use crate::rng::Rng;

    fn sample_store() -> ParamStore {
        let mut rng = Rng::seed_from_u64(0);
        let mut store = ParamStore::new();
        store.add_stiefel_group("w", 3, 4, 8, &mut rng);
        store.add_free("head", MatF::randn(5, 2, &mut rng));
        store.add_stiefel_keyed("x", stiefel::random_point(2, 6, &mut rng), "solo");
        store
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pogo_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let store = sample_store();
        let path = tmp("roundtrip");
        save(&store, 1234, &path).unwrap();
        let (back, step) = load(&path).unwrap();
        assert_eq!(step, 1234);
        assert_eq!(back.len(), store.len());
        for (a, b) in store.params().iter().zip(back.params()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.constraint, b.constraint);
            assert_eq!(a.group_key, b.group_key);
            assert_eq!(a.mat, b.mat, "bit-exact restore for {}", a.name);
        }
        // Grouping structure survives.
        assert_eq!(back.stiefel_groups().len(), store.stiefel_groups().len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn f64_roundtrip_bit_exact() {
        let mut rng = Rng::seed_from_u64(7);
        let mut store: ParamStore<f64> = ParamStore::new();
        store.add_stiefel_group("w", 2, 3, 7, &mut rng);
        store.add_free("b", MatD::randn(4, 4, &mut rng));
        let path = tmp("f64");
        save_t::<f64>(&store, 9, &path).unwrap();
        let (back, step) = load_t::<f64>(&path).unwrap();
        assert_eq!(step, 9);
        for (a, b) in store.params().iter().zip(back.params()) {
            assert_eq!(a.mat, b.mat, "bit-exact f64 restore for {}", a.name);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn complex_roundtrip_bit_exact() {
        // c64 and c128: interleaved re,im pairs restore bit-for-bit, with
        // group keys intact so `stiefel_groups` re-partitions identically.
        let mut rng = Rng::seed_from_u64(11);
        let mut store: ParamStore<crate::linalg::Complex<f32>> = ParamStore::new();
        store.add_unitary_group("cores", 3, 2, 5, &mut rng);
        let path = tmp("c64");
        save_t(&store, 77, &path).unwrap();
        let (back, step) = load_t::<crate::linalg::Complex<f32>>(&path).unwrap();
        assert_eq!(step, 77);
        assert_eq!(back.len(), store.len());
        for (a, b) in store.params().iter().zip(back.params()) {
            assert_eq!(a.mat, b.mat, "bit-exact c64 restore for {}", a.name);
            assert_eq!(a.group_key, b.group_key);
        }
        assert_eq!(back.stiefel_groups().len(), store.stiefel_groups().len());
        std::fs::remove_file(&path).ok();

        let mut rng = Rng::seed_from_u64(12);
        let mut s128: ParamStore<crate::linalg::Complex<f64>> = ParamStore::new();
        s128.add_unitary_group("w", 2, 3, 4, &mut rng);
        let path = tmp("c128");
        save_t(&s128, 5, &path).unwrap();
        let (back, _) = load_t::<crate::linalg::Complex<f64>>(&path).unwrap();
        for (a, b) in s128.params().iter().zip(back.params()) {
            assert_eq!(a.mat, b.mat, "bit-exact c128 restore for {}", a.name);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn complex_dtype_mismatch_rejected() {
        // A c64 checkpoint is never reinterpreted as f32 (same 8-byte
        // stride per 2 real words — silent aliasing would "work").
        let mut rng = Rng::seed_from_u64(13);
        let mut store: ParamStore<crate::linalg::Complex<f32>> = ParamStore::new();
        store.add_unitary_group("x", 1, 2, 4, &mut rng);
        let path = tmp("c64_mismatch");
        save_t(&store, 1, &path).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("dtype is c64"), "{err:#}");
        let err = load_t::<crate::linalg::Complex<f64>>(&path).unwrap_err();
        assert!(format!("{err:#}").contains("dtype is c64"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dtype_mismatch_rejected_both_ways() {
        let store = sample_store();
        let p32 = tmp("dtype32");
        save(&store, 1, &p32).unwrap();
        let err = load_t::<f64>(&p32).unwrap_err();
        assert!(format!("{err:#}").contains("dtype is f32"), "{err:#}");

        let mut rng = Rng::seed_from_u64(8);
        let mut s64: ParamStore<f64> = ParamStore::new();
        s64.add_stiefel_group("w", 1, 2, 4, &mut rng);
        let p64 = tmp("dtype64");
        save_t::<f64>(&s64, 1, &p64).unwrap();
        let err = load(&p64).unwrap_err();
        assert!(format!("{err:#}").contains("dtype is f64"), "{err:#}");
        std::fs::remove_file(&p32).ok();
        std::fs::remove_file(&p64).ok();
    }

    #[test]
    fn corrupt_blob_rejected() {
        let store = sample_store();
        let path = tmp("corrupt");
        save(&store, 1, &path).unwrap();
        // Flip a byte near the end.
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_rejected() {
        let store = sample_store();
        let path = tmp("trunc");
        save(&store, 1, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_mid_header_rejected_with_context() {
        let store = sample_store();
        let path = tmp("trunc_header");
        save(&store, 1, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Keep the length word plus a sliver of the header.
        std::fs::write(&path, &bytes[..10]).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("header"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_header_json_rejected() {
        let path = tmp("garbage");
        let header = b"not json at all";
        let mut bytes = (header.len() as u32).to_le_bytes().to_vec();
        bytes.extend_from_slice(header);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt checkpoint header"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let path = tmp("magic");
        std::fs::write(&path, b"\x10\x00\x00\x00{\"magic\":\"nope\"}  ").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
