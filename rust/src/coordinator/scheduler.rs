//! Learning-rate schedules and early stopping.
//!
//! The paper's experiment protocol (§5.3/§C.4): halve the learning rate
//! when the validation loss plateaus for `patience` epochs, early-stop on
//! the validation set, and (Fig. 4) stop when the optimality gap reaches a
//! target. All of those policies live here, decoupled from the optimizers
//! via `Orthoptimizer::set_lr`.

/// Learning-rate schedule.
#[derive(Clone, Debug)]
pub enum LrSchedule {
    Constant,
    /// Multiply by `factor` when the monitored value hasn't improved by
    /// `min_delta` for `patience` consecutive observations (paper: halve
    /// on a 10-epoch plateau).
    Plateau { patience: usize, factor: f64, min_delta: f64 },
    /// Multiply by `gamma` every `every` observations.
    Step { every: usize, gamma: f64 },
    /// Cosine decay from the initial lr to `final_frac`·lr over `total`.
    Cosine { total: usize, final_frac: f64 },
}

/// Stateful scheduler driving one optimizer's lr.
#[derive(Clone, Debug)]
pub struct Scheduler {
    schedule: LrSchedule,
    base_lr: f64,
    lr: f64,
    best: f64,
    wait: usize,
    ticks: usize,
}

impl Scheduler {
    pub fn new(schedule: LrSchedule, base_lr: f64) -> Self {
        Scheduler { schedule, base_lr, lr: base_lr, best: f64::INFINITY, wait: 0, ticks: 0 }
    }

    pub fn lr(&self) -> f64 {
        self.lr
    }

    /// Observe the monitored value (lower = better); returns the new lr.
    pub fn observe(&mut self, value: f64) -> f64 {
        self.ticks += 1;
        match &self.schedule {
            LrSchedule::Constant => {}
            LrSchedule::Plateau { patience, factor, min_delta } => {
                if value < self.best - *min_delta {
                    self.best = value;
                    self.wait = 0;
                } else {
                    self.wait += 1;
                    if self.wait >= *patience {
                        self.lr *= factor;
                        self.wait = 0;
                        log::debug!("plateau: lr → {:.3e}", self.lr);
                    }
                }
            }
            LrSchedule::Step { every, gamma } => {
                if self.ticks % every == 0 {
                    self.lr *= gamma;
                }
            }
            LrSchedule::Cosine { total, final_frac } => {
                let t = (self.ticks.min(*total)) as f64 / *total as f64;
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                self.lr = self.base_lr * (final_frac + (1.0 - final_frac) * cos);
            }
        }
        self.lr
    }
}

/// Early stopping on a monitored value (lower = better).
#[derive(Clone, Debug)]
pub struct EarlyStop {
    pub patience: usize,
    pub min_delta: f64,
    best: f64,
    wait: usize,
}

impl EarlyStop {
    pub fn new(patience: usize, min_delta: f64) -> Self {
        EarlyStop { patience, min_delta, best: f64::INFINITY, wait: 0 }
    }

    /// Observe; returns true when training should stop.
    pub fn observe(&mut self, value: f64) -> bool {
        if value < self.best - self.min_delta {
            self.best = value;
            self.wait = 0;
            false
        } else {
            self.wait += 1;
            self.wait >= self.patience
        }
    }

    pub fn best(&self) -> f64 {
        self.best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plateau_halves_after_patience() {
        let mut s =
            Scheduler::new(LrSchedule::Plateau { patience: 3, factor: 0.5, min_delta: 0.0 },
                           1.0);
        s.observe(10.0); // best=10
        assert_eq!(s.lr(), 1.0);
        s.observe(10.0);
        s.observe(10.0);
        let lr = s.observe(10.0); // 3rd non-improvement → halve
        assert_eq!(lr, 0.5);
        // Improvement resets.
        s.observe(5.0);
        s.observe(6.0);
        s.observe(6.0);
        assert_eq!(s.lr(), 0.5);
        assert_eq!(s.observe(6.0), 0.25);
    }

    #[test]
    fn step_decays_on_schedule() {
        let mut s = Scheduler::new(LrSchedule::Step { every: 2, gamma: 0.1 }, 1.0);
        s.observe(0.0);
        assert_eq!(s.lr(), 1.0);
        s.observe(0.0);
        assert!((s.lr() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn cosine_endpoints() {
        let mut s = Scheduler::new(LrSchedule::Cosine { total: 10, final_frac: 0.1 }, 2.0);
        let mut last = 2.0;
        for _ in 0..10 {
            last = s.observe(0.0);
        }
        assert!((last - 0.2).abs() < 1e-9, "final lr {last}");
    }

    #[test]
    fn early_stop_fires() {
        let mut es = EarlyStop::new(2, 1e-9);
        assert!(!es.observe(1.0));
        assert!(!es.observe(0.5));
        assert!(!es.observe(0.5));
        assert!(es.observe(0.6));
        assert_eq!(es.best(), 0.5);
    }
}
