//! L3 coordinator: the training runtime that makes thousands of
//! orthogonality-constrained matrices practical.
//!
//! - [`param_store`] — named parameters, shape-grouped for batched dispatch;
//! - [`engine`] — serializable optimizer specs ([`OptimizerSpec`]) and
//!   Rust-vs-XLA engine dispatch (construction itself lives in the method
//!   registry, `crate::optim::registry`);
//! - [`session`] — [`OptimSession`], the per-shape-group steppers behind
//!   one handle (the extract → batched-step → write-back loop);
//! - [`trainer`] — the step loop (grads → session apply → free-param Adam
//!   → schedules → telemetry);
//! - [`scheduler`] — plateau-halving / step / cosine lr + early stopping;
//! - [`metrics`] — wall-clock series, CSV/JSONL sinks, grid interpolation.

pub mod checkpoint;
pub mod engine;
pub mod metrics;
pub mod param_store;
pub mod report;
pub mod scheduler;
pub mod session;
pub mod trainer;

pub use engine::OptimizerSpec;
pub use metrics::MetricLog;
pub use param_store::{Constraint, Group, Param, ParamStore};
pub use scheduler::{EarlyStop, LrSchedule, Scheduler};
pub use session::OptimSession;
pub use trainer::{GradSource, Trainer, TrainerConfig};
