//! L3 coordinator: the training runtime that makes thousands of
//! orthogonality-constrained matrices practical.
//!
//! - [`param_store`] — named parameters, shape-grouped for batched dispatch;
//! - [`engine`] — optimizer specs and Rust-vs-XLA engine construction;
//! - [`trainer`] — the step loop (grads → grouped constrained updates →
//!   free-param Adam → schedules → telemetry);
//! - [`scheduler`] — plateau-halving / step / cosine lr + early stopping;
//! - [`metrics`] — wall-clock series, CSV/JSONL sinks, grid interpolation.

pub mod checkpoint;
pub mod engine;
pub mod metrics;
pub mod param_store;
pub mod report;
pub mod scheduler;
pub mod trainer;

pub use engine::OptimizerSpec;
pub use metrics::MetricLog;
pub use param_store::{Constraint, Group, Param, ParamStore};
pub use scheduler::{EarlyStop, LrSchedule, Scheduler};
pub use trainer::{GradSource, Trainer, TrainerConfig};
