//! API-compatible stub of the `xla` (PJRT) bindings.
//!
//! The offline build environment does not ship the real `xla` crate, so the
//! runtime modules alias this stub in its place (`use super::xla_stub as
//! xla`). Input marshalling ([`Literal`]) works for real; anything that
//! would need the native runtime — parsing HLO, compiling, executing —
//! returns [`XlaError`], which the registry/stepper/engine layers surface
//! as ordinary `anyhow` errors. The pure-Rust engine is unaffected.
//!
//! To link the real bindings, add the `xla` crate to rust/Cargo.toml and
//! re-point the three `use super::xla_stub as xla;` aliases in
//! src/runtime/{client,exec,registry}.rs.

use std::fmt;
use std::marker::PhantomData;
use std::rc::Rc;

const UNAVAILABLE: &str = "XLA/PJRT runtime unavailable: this build links the in-crate stub \
     (rust/src/runtime/xla_stub.rs); use Engine::Rust, or link the real `xla` bindings";

/// Error type of every stub operation.
pub struct XlaError(String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type XResult<T> = Result<T, XlaError>;

fn unavailable<T>() -> XResult<T> {
    Err(XlaError(UNAVAILABLE.to_string()))
}

/// Parsed HLO module (never constructible through the stub).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XResult<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle. Deliberately `!Send` (mirrors the real bindings,
/// which wrap an `Rc`); see `runtime::client` for the thread-local story.
pub struct PjRtClient {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtClient {
    pub fn cpu() -> XResult<PjRtClient> {
        Ok(PjRtClient { _not_send: PhantomData })
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> XResult<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Compiled executable (never constructible through the stub).
pub struct PjRtLoadedExecutable {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> XResult<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle (never constructible through the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XResult<Literal> {
        unavailable()
    }
}

/// Storage of a [`Literal`] — public only because the [`Element`] trait
/// mentions it; not part of the mirrored API.
#[doc(hidden)]
#[derive(Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Host-side literal: typed buffer + dims. Fully functional (marshalling
/// does not need the native runtime).
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Element types a [`Literal`] can hold.
pub trait Element: Copy + Sized {
    #[doc(hidden)]
    fn wrap(values: &[Self]) -> Data;
    #[doc(hidden)]
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl Element for f32 {
    fn wrap(values: &[Self]) -> Data {
        Data::F32(values.to_vec())
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl Element for i32 {
    fn wrap(values: &[Self]) -> Data {
        Data::I32(values.to_vec())
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Element>(values: &[T]) -> Literal {
        Literal { data: T::wrap(values), dims: vec![values.len() as i64] }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> XResult<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: Element>(&self) -> XResult<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| XlaError("literal dtype mismatch".to_string()))
    }

    /// Flatten a tuple literal (outputs only exist with a real runtime).
    pub fn to_tuple(self) -> XResult<Vec<Literal>> {
        unavailable()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_marshalling_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let comp = XlaComputation { _priv: () };
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("stub"));
    }
}
