//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! L3 hot path. See /opt/xla-example/README.md for the interchange-format
//! rationale (HLO text, not serialized protos).

mod client;
mod exec;
mod registry;
pub mod stepper;
pub mod xla_stub;

pub use client::with_client;
pub use exec::{
    literal_to_mat, literal_to_scalar, literal_to_vec, pack_batch, unpack_batch, Arg,
    Executable,
};
pub use registry::{EntryMeta, Registry, TensorSig};
