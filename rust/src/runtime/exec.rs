//! Executable wrapper + literal marshalling between `Mat` and PJRT.
//!
//! Buffers are row-major on both sides, so marshalling is a memcpy. The
//! batched helpers pack a same-shape group `[Mat; B]` into one `(B, p, n)`
//! literal — that packing is the scalability mechanism of the paper's
//! Fig. 1 (one dispatch for 10⁴ kernels instead of 10⁴ QR calls).

use super::registry::EntryMeta;
use super::xla_stub as xla;
use anyhow::{anyhow, Result};
use crate::linalg::MatF;

/// A compiled program plus its manifest signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: EntryMeta,
}

/// An input value for a program.
pub enum Arg<'a> {
    /// One matrix (its (p, n) shape must match the signature).
    Mat(&'a MatF),
    /// A same-shape group packed as (B, p, n).
    Batch(&'a [MatF]),
    /// Raw f32 buffer with explicit dims.
    F32(&'a [f32], Vec<usize>),
    /// Raw i32 buffer with explicit dims.
    I32(&'a [i32], Vec<usize>),
    /// Shape-(1,) scalar (e.g. the runtime learning rate).
    Scalar(f32),
}

impl Executable {
    pub fn new(exe: xla::PjRtLoadedExecutable, meta: EntryMeta) -> Self {
        Executable { exe, meta }
    }

    /// Execute with the given arguments; returns the flattened output
    /// tuple as literals.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.meta.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                args.len()
            ));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, sig) in args.iter().zip(&self.meta.inputs) {
            literals.push(self.to_literal(arg, sig)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.meta.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {} output: {e:?}", self.meta.name))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        out.to_tuple().map_err(|e| anyhow!("untupling {}: {e:?}", self.meta.name))
    }

    fn to_literal(&self, arg: &Arg, sig: &super::registry::TensorSig) -> Result<xla::Literal> {
        let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
        let lit = match arg {
            Arg::Mat(m) => {
                let want: Vec<usize> = sig.shape.clone();
                let have = vec![m.rows(), m.cols()];
                if want != have {
                    return Err(anyhow!(
                        "{}.{}: shape mismatch {want:?} vs {have:?}",
                        self.meta.name,
                        sig.name
                    ));
                }
                xla::Literal::vec1(m.as_slice()).reshape(&dims)?
            }
            Arg::Batch(mats) => {
                let packed = pack_batch(mats)?;
                let have = vec![mats.len(), mats[0].rows(), mats[0].cols()];
                if sig.shape != have {
                    return Err(anyhow!(
                        "{}.{}: batch shape mismatch {:?} vs {have:?}",
                        self.meta.name,
                        sig.name,
                        sig.shape
                    ));
                }
                xla::Literal::vec1(&packed).reshape(&dims)?
            }
            Arg::F32(buf, shape) => {
                if &sig.shape != shape || buf.len() != sig.elements() {
                    return Err(anyhow!(
                        "{}.{}: f32 shape mismatch {:?} vs {shape:?} (len {})",
                        self.meta.name,
                        sig.name,
                        sig.shape,
                        buf.len()
                    ));
                }
                xla::Literal::vec1(buf).reshape(&dims)?
            }
            Arg::I32(buf, shape) => {
                if &sig.shape != shape || buf.len() != sig.elements() {
                    return Err(anyhow!(
                        "{}.{}: i32 shape mismatch {:?} vs {shape:?}",
                        self.meta.name,
                        sig.name,
                        sig.shape
                    ));
                }
                xla::Literal::vec1(buf).reshape(&dims)?
            }
            Arg::Scalar(v) => xla::Literal::vec1(&[*v][..]).reshape(&dims)?,
        };
        Ok(lit)
    }
}

/// Pack a same-shape group into a contiguous (B, p, n) row-major buffer.
pub fn pack_batch(mats: &[MatF]) -> Result<Vec<f32>> {
    let first = mats.first().ok_or_else(|| anyhow!("empty batch"))?;
    let (p, n) = first.shape();
    let mut out = Vec::with_capacity(mats.len() * p * n);
    for m in mats {
        if m.shape() != (p, n) {
            return Err(anyhow!("ragged batch: {:?} vs {:?}", m.shape(), (p, n)));
        }
        out.extend_from_slice(m.as_slice());
    }
    Ok(out)
}

/// Unpack a (B, p, n) literal back into `B` matrices.
pub fn unpack_batch(lit: &xla::Literal, b: usize, p: usize, n: usize) -> Result<Vec<MatF>> {
    let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    if v.len() != b * p * n {
        return Err(anyhow!("unpack size mismatch: {} vs {}", v.len(), b * p * n));
    }
    Ok((0..b).map(|i| MatF::from_vec(p, n, v[i * p * n..(i + 1) * p * n].to_vec())).collect())
}

/// Read a literal as one matrix.
pub fn literal_to_mat(lit: &xla::Literal, p: usize, n: usize) -> Result<MatF> {
    let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    if v.len() != p * n {
        return Err(anyhow!("literal size {} vs {}x{}", v.len(), p, n));
    }
    Ok(MatF::from_vec(p, n, v))
}

/// Read a literal as an f32 vector.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

/// Read a scalar (or shape-(1,)/()-shaped) literal.
pub fn literal_to_scalar(lit: &xla::Literal) -> Result<f32> {
    let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn pack_unpack_roundtrip_shapes() {
        let mut rng = Rng::seed_from_u64(0);
        let mats: Vec<MatF> = (0..3).map(|_| MatF::randn(4, 5, &mut rng)).collect();
        let packed = pack_batch(&mats).unwrap();
        assert_eq!(packed.len(), 60);
        assert_eq!(&packed[0..20], mats[0].as_slice());
        assert_eq!(&packed[40..60], mats[2].as_slice());
    }

    #[test]
    fn ragged_batch_rejected() {
        let a = MatF::zeros(2, 2);
        let b = MatF::zeros(2, 3);
        assert!(pack_batch(&[a, b]).is_err());
    }
}
