//! Thread-local PJRT CPU client.
//!
//! The xla crate's `PjRtClient` wraps an `Rc` (not `Send`), so a global
//! static is impossible; instead each thread that touches the runtime gets
//! one lazily-created client. The coordinator's step loop is
//! single-threaded, so in practice the process has exactly one client —
//! tests that exercise the runtime from multiple test threads each get
//! their own, which XLA's CPU plugin supports.
//!
//! Client creation is fallible (missing PJRT plugin, exhausted devices):
//! the error propagates through the crate's fallible optimizer API
//! instead of panicking inside the runtime.

use super::xla_stub as xla;
use anyhow::{anyhow, Result};
use std::cell::OnceCell;

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// Run `f` with this thread's PJRT CPU client (created on first use).
/// Returns `Err` if the client cannot be created — callers bubble this
/// through the `Result` chain (Trainer/CLI) rather than unwinding.
pub fn with_client<R>(f: impl FnOnce(&xla::PjRtClient) -> R) -> Result<R> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            // Silence XLA's stderr chatter unless the user asked for it.
            if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
                std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
            }
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
            log::debug!(
                "PJRT client: platform={} devices={}",
                client.platform_name(),
                client.device_count()
            );
            let _ = cell.set(client);
        }
        Ok(f(cell.get().expect("client initialized above")))
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn client_initializes_and_reuses_or_errors_cleanly() {
        // With a real PJRT plugin both calls succeed and agree; with the
        // offline stub both fail with the same clean (non-panicking)
        // error path.
        let d1 = super::with_client(|c| c.device_count());
        let d2 = super::with_client(|c| c.device_count());
        match (d1, d2) {
            (Ok(a), Ok(b)) => {
                assert!(a >= 1);
                assert_eq!(a, b);
            }
            (Err(e1), Err(e2)) => {
                assert!(format!("{e1}").contains("PJRT"), "{e1}");
                assert!(format!("{e2}").contains("PJRT"), "{e2}");
            }
            other => panic!("inconsistent client results: {other:?}"),
        }
    }
}
