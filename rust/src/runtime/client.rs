//! Thread-local PJRT CPU client.
//!
//! The xla crate's `PjRtClient` wraps an `Rc` (not `Send`), so a global
//! static is impossible; instead each thread that touches the runtime gets
//! one lazily-created client. The coordinator's step loop is
//! single-threaded, so in practice the process has exactly one client —
//! tests that exercise the runtime from multiple test threads each get
//! their own, which XLA's CPU plugin supports.

use super::xla_stub as xla;
use std::cell::OnceCell;

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// Run `f` with this thread's PJRT CPU client (created on first use).
pub fn with_client<R>(f: impl FnOnce(&xla::PjRtClient) -> R) -> R {
    CLIENT.with(|cell| {
        let client = cell.get_or_init(|| {
            // Silence XLA's stderr chatter unless the user asked for it.
            if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
                std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
            }
            let client = xla::PjRtClient::cpu().expect("creating PJRT CPU client");
            log::debug!(
                "PJRT client: platform={} devices={}",
                client.platform_name(),
                client.device_count()
            );
            client
        });
        f(client)
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn client_initializes_and_reuses() {
        let d1 = super::with_client(|c| c.device_count());
        let d2 = super::with_client(|c| c.device_count());
        assert!(d1 >= 1);
        assert_eq!(d1, d2);
    }
}
