//! XLA-backed orthoptimizer steppers: the paper's matmul-only methods
//! dispatched as ONE batched PJRT execution per same-shape group.
//!
//! This is the accelerated engine of the comparison (`Engine::Xla`):
//! the Rust coordinator packs a group's matrices into a `(B, p, n)`
//! literal, runs the AOT step program (whose core is the L1 Pallas
//! kernel), and unpacks the updated points. Integration tests assert
//! step-for-step agreement with the pure-Rust engine.

use super::exec::{self, Arg};
use super::registry::Registry;
use crate::linalg::MatF;
use crate::optim::base::{BaseOpt, BaseOptKind};
use crate::optim::quartic::solve_landing_quartic;
use anyhow::{anyhow, Result};
use std::rc::Rc;

/// Which step program a stepper drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    Pogo,
    PogoVadam,
    PogoFindRoot,
    Landing,
    Slpg,
}

impl StepKind {
    fn prefix(&self) -> &'static str {
        match self {
            StepKind::Pogo => "pogo_step",
            StepKind::PogoVadam => "pogo_vadam_step",
            StepKind::PogoFindRoot => "pogo_coeffs",
            StepKind::Landing => "landing_step",
            StepKind::Slpg => "slpg_step",
        }
    }
}

/// Artifact name for a step program at a group shape.
pub fn step_artifact_name(kind: StepKind, b: usize, p: usize, n: usize) -> String {
    format!("{}_b{b}_{p}x{n}", kind.prefix())
}

/// An XLA-backed stepper for one same-shape group.
pub struct XlaStepper {
    kind: StepKind,
    pub lr: f64,
    /// Landing attraction strength λ_a (runtime argument of the program).
    pub attraction: f64,
    /// LandingPC: normalize each gradient to unit Frobenius norm on L3
    /// before packing (elementwise, negligible cost).
    pub normalize_grad: bool,
    /// Landing safe-ball radius ε (safeguard computed in-graph);
    /// LandingPC sets this huge to disable the safeguard per its paper.
    pub eps_ball: f64,
    /// Host-side base optimizer (§3.1) applied to gradients before the
    /// geometry dispatch — elementwise, so it costs nothing next to the
    /// executable. `PogoVadam` fuses VAdam in-graph and skips this.
    base: Option<BaseOpt<f32>>,
    shape: (usize, usize, usize),
    exe: Rc<super::exec::Executable>,
    /// FindRoot needs the companion normal-step program.
    normal_exe: Option<Rc<super::exec::Executable>>,
    // VAdam state (packed in group layout).
    m: Option<Vec<f32>>,
    v: Option<Vec<f32>>,
    t: u64,
    /// λ values chosen on the last FindRoot step (telemetry).
    pub last_lambdas: Vec<f64>,
}

impl XlaStepper {
    /// Build a stepper for a `(b, p, n)` group; the matching artifact must
    /// exist in the registry (aot.py emits one per experiment group shape).
    pub fn new(
        reg: &Registry,
        kind: StepKind,
        lr: f64,
        b: usize,
        p: usize,
        n: usize,
    ) -> Result<XlaStepper> {
        let name = step_artifact_name(kind, b, p, n);
        let exe = reg
            .get(&name)
            .map_err(|e| anyhow!("{e}; rebuild artifacts with shape (b={b},{p}x{n})"))?;
        let normal_exe = if kind == StepKind::PogoFindRoot {
            Some(reg.get(&format!("pogo_normal_b{b}_{p}x{n}"))?)
        } else {
            None
        };
        Ok(XlaStepper {
            kind,
            lr,
            attraction: 1.0,
            normalize_grad: false,
            eps_ball: 0.5,
            base: None,
            shape: (b, p, n),
            exe,
            normal_exe,
            m: None,
            v: None,
            t: 0,
            last_lambdas: Vec::new(),
        })
    }

    pub fn kind(&self) -> StepKind {
        self.kind
    }

    /// Install a host-side base optimizer (must be linear — Def. 1 — for
    /// tangent-space semantics; ignored for the fused-VAdam kind).
    pub fn set_base(&mut self, kind: BaseOptKind) {
        if self.kind != StepKind::PogoVadam && kind != BaseOptKind::Sgd {
            self.base = Some(BaseOpt::new(kind, self.shape.0));
        }
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        self.shape
    }

    /// One batched step over the whole group (in place).
    pub fn step_group(&mut self, xs: &mut [MatF], gs: &[MatF]) -> Result<()> {
        let (b, p, n) = self.shape;
        if xs.len() != b || gs.len() != b {
            return Err(anyhow!("group size {} vs stepper batch {b}", xs.len()));
        }
        // Host-side base-optimizer transform (momentum/VAdam), if any.
        let gs_base: Vec<MatF>;
        let gs: &[MatF] = match &mut self.base {
            Some(base) => {
                gs_base =
                    gs.iter().enumerate().map(|(i, g)| base.transform(i, g)).collect();
                &gs_base
            }
            None => gs,
        };
        match self.kind {
            StepKind::Pogo | StepKind::Slpg => {
                let outs = self.exe.run(&[
                    Arg::Batch(xs),
                    Arg::Batch(gs),
                    Arg::Scalar(self.lr as f32),
                ])?;
                let new = exec::unpack_batch(&outs[0], b, p, n)?;
                xs.clone_from_slice(&new);
            }
            StepKind::Landing => {
                // landing_step returns (X⁺, distances); the fixed-step
                // program relies on L3 keeping η in the safe regime.
                // LandingPC semantics: per-matrix unit-normalized grads.
                let gs_owned: Vec<MatF>;
                let gs_eff: &[MatF] = if self.normalize_grad {
                    gs_owned = gs
                        .iter()
                        .map(|g| {
                            let nrm = g.norm().max(1e-30);
                            g.scale(1.0 / nrm)
                        })
                        .collect();
                    &gs_owned
                } else {
                    gs
                };
                let outs = self.exe.run(&[
                    Arg::Batch(xs),
                    Arg::Batch(gs_eff),
                    Arg::Scalar(self.lr as f32),
                    Arg::Scalar(self.attraction as f32),
                    Arg::Scalar(self.eps_ball as f32),
                ])?;
                let new = exec::unpack_batch(&outs[0], b, p, n)?;
                xs.clone_from_slice(&new);
            }
            StepKind::PogoVadam => {
                let sz = b * p * n;
                let m = self.m.get_or_insert_with(|| vec![0.0; sz]).clone();
                let v = self.v.get_or_insert_with(|| vec![0.0; b]).clone();
                self.t += 1;
                let outs = self.exe.run(&[
                    Arg::Batch(xs),
                    Arg::Batch(gs),
                    Arg::F32(&m, vec![b, p, n]),
                    Arg::F32(&v, vec![b, 1, 1]),
                    Arg::Scalar(self.t as f32),
                    Arg::Scalar(self.lr as f32),
                ])?;
                let new = exec::unpack_batch(&outs[0], b, p, n)?;
                xs.clone_from_slice(&new);
                self.m = Some(exec::literal_to_vec(&outs[1])?);
                self.v = Some(exec::literal_to_vec(&outs[2])?);
            }
            StepKind::PogoFindRoot => {
                // Phase 1: intermediate M + quartic coefficients on XLA.
                let outs = self.exe.run(&[
                    Arg::Batch(xs),
                    Arg::Batch(gs),
                    Arg::Scalar(self.lr as f32),
                ])?;
                let m_flat = exec::literal_to_vec(&outs[0])?;
                let coeffs = exec::literal_to_vec(&outs[1])?; // (B, 5)
                // Phase 2: solve each quartic on L3 (microseconds)…
                self.last_lambdas.clear();
                let mut lams = Vec::with_capacity(b);
                for i in 0..b {
                    let c = &coeffs[i * 5..(i + 1) * 5];
                    let lam =
                        solve_landing_quartic([c[0] as f64, c[1] as f64, c[2] as f64,
                                               c[3] as f64, c[4] as f64]);
                    self.last_lambdas.push(lam);
                    lams.push(lam as f32);
                }
                // …Phase 3: per-matrix normal step back on XLA.
                let normal = self.normal_exe.as_ref().unwrap();
                let outs = normal.run(&[
                    Arg::F32(&m_flat, vec![b, p, n]),
                    Arg::F32(&lams, vec![b]),
                ])?;
                let new = exec::unpack_batch(&outs[0], b, p, n)?;
                xs.clone_from_slice(&new);
            }
        }
        Ok(())
    }
}

/// Adapter implementing the generic `Orthoptimizer` trait over one group.
/// Errors (missing artifact, shape mismatch, dispatch failure) are
/// forwarded, not panicked. `step(idx, …)` only succeeds for a batch-1
/// stepper — the batched engine's unit of work is `step_group`.
impl crate::optim::Orthoptimizer<f32> for XlaStepper {
    fn step(&mut self, _idx: usize, x: &mut MatF, g: &MatF) -> Result<()> {
        // In-place view, no intermediate Vec copy.
        XlaStepper::step_group(self, std::slice::from_mut(x), std::slice::from_ref(g))
    }

    fn step_group(&mut self, xs: &mut [MatF], gs: &[MatF]) -> Result<()> {
        XlaStepper::step_group(self, xs, gs)
    }

    fn name(&self) -> &str {
        match self.kind {
            StepKind::Pogo => "POGO[xla]",
            StepKind::PogoVadam => "POGO(vadam)[xla]",
            StepKind::PogoFindRoot => "POGO-root[xla]",
            StepKind::Landing => "Landing[xla]",
            StepKind::Slpg => "SLPG[xla]",
        }
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn last_lambda(&self) -> Option<f64> {
        self.last_lambdas.last().copied()
    }
}
