//! Artifact registry: manifest loading + executable compilation cache.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) maps
//! program names to HLO-text files plus input/output signatures. The
//! registry compiles each program once on first use and caches the PJRT
//! executable for the rest of the process lifetime — compile time is paid
//! at startup (or first dispatch), never in the step loop.

use super::client;
use super::exec::Executable;
use super::xla_stub as xla;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

/// Shape + dtype of one program input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct EntryMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub tags: Vec<String>,
}

/// The artifact registry (open once, share via `Rc`).
pub struct Registry {
    dir: PathBuf,
    entries: HashMap<String, EntryMeta>,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Registry {
    /// Open the registry at `dir` (must contain `manifest.json`).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Registry> {
        let dir = dir.into();
        let manifest_path = dir.join("manifest.json");
        let manifest = Json::parse_file(&manifest_path)
            .with_context(|| format!("loading manifest {}", manifest_path.display()))?;
        let mut entries = HashMap::new();
        let obj = manifest
            .get("entries")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest has no 'entries' object"))?;
        for (name, e) in obj {
            let parse_sigs = |key: &str| -> Vec<TensorSig> {
                e.get(key)
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|t| TensorSig {
                        name: t.get("name").as_str().unwrap_or("").to_string(),
                        shape: t
                            .get("shape")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|d| d.as_usize())
                            .collect(),
                        dtype: t.get("dtype").as_str().unwrap_or("float32").to_string(),
                    })
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntryMeta {
                    name: name.clone(),
                    file: e.get("file").as_str().unwrap_or("").to_string(),
                    inputs: parse_sigs("inputs"),
                    outputs: parse_sigs("outputs"),
                    tags: e
                        .get("tags")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|t| t.as_str().map(str::to_string))
                        .collect(),
                },
            );
        }
        log::info!("registry: {} programs at {}", entries.len(), dir.display());
        Ok(Registry { dir, entries, cache: RefCell::new(HashMap::new()) })
    }

    /// Open the default repository registry (`<repo>/artifacts`).
    pub fn open_default() -> Result<Registry> {
        Self::open(crate::artifacts_dir())
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    pub fn has(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn meta(&self, name: &str) -> Option<&EntryMeta> {
        self.entries.get(name)
    }

    /// Names with a given tag (e.g. all `"step"` programs).
    pub fn with_tag(&self, tag: &str) -> Vec<&EntryMeta> {
        let mut v: Vec<&EntryMeta> =
            self.entries.values().filter(|e| e.tags.iter().any(|t| t == tag)).collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Get (compiling + caching on first use) an executable by name.
    pub fn get(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta =
            self.entries.get(name).ok_or_else(|| anyhow!("no artifact named '{name}'"))?;
        let path = self.dir.join(&meta.file);
        let t = crate::util::Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client::with_client(|client| {
            client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))
        })??;
        log::debug!("compiled {name} in {:.0}ms", t.millis());
        let exe = Rc::new(Executable::new(exe, meta.clone()));
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_registry() -> Option<Registry> {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts built (run `make artifacts`)");
            return None;
        }
        Some(Registry::open(dir).unwrap())
    }

    #[test]
    fn manifest_parses_and_lists() {
        let Some(reg) = test_registry() else { return };
        assert!(reg.has("pogo_step_b4_8x16"));
        let meta = reg.meta("pogo_step_b4_8x16").unwrap();
        assert_eq!(meta.inputs.len(), 3);
        assert_eq!(meta.inputs[0].shape, vec![4, 8, 16]);
        assert!(!reg.with_tag("step").is_empty());
    }

    #[test]
    fn compile_caches() {
        let Some(reg) = test_registry() else { return };
        let a = reg.get("distance_b4_8x16").unwrap();
        let b = reg.get("distance_b4_8x16").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn unknown_name_is_error() {
        let Some(reg) = test_registry() else { return };
        assert!(reg.get("nope").is_err());
    }
}
