//! The (real and complex) Stiefel manifold of row-orthonormal matrices.
//!
//! `St(p, n) = { X ∈ F^{p×n} : X Xᵀ (or X X^H) = I_p }`, `p ≤ n` — the
//! feasible set of every experiment in the paper (Eq. 2). This module hosts
//! the geometric primitives shared by all orthoptimizers:
//!
//! - random points (Gaussian + QR / polar),
//! - the squared-distance potential `N(X) = ¼‖X Xᵀ − I‖²` and its gradient
//!   `∇N(X) = (X Xᵀ − I) X` (Landing's attraction field, Eq. 6),
//! - the relative gradient `S = Skew(Xᵀ G)` and the Riemannian gradient
//!   `X S` under the Euclidean metric (§2),
//! - projections (polar = closest point; QR = retraction baseline).

use crate::linalg::{
    matmul, matmul_a_bh, matmul_a_bt, matmul_ah_b, matmul_at_b, polar_project,
    qr_retract_rows, CMat, Field, Mat, PolarOpts, Scalar,
};
use crate::rng::Rng;

/// Random point on St(p, n) (f32 convenience used across experiments).
pub fn random_point(p: usize, n: usize, rng: &mut Rng) -> Mat<f32> {
    random_point_t(p, n, rng)
}

/// Random point on St(p, n), generic in precision. Gaussian then QR, which
/// gives Haar-distributed rows.
pub fn random_point_t<S: Scalar>(p: usize, n: usize, rng: &mut Rng) -> Mat<S> {
    assert!(p <= n, "St(p, n) needs p ≤ n, got ({p}, {n})");
    qr_retract_rows(&Mat::<S>::randn(p, n, rng))
}

/// Random point on the complex Stiefel manifold (X Xᴴ = I), via complex
/// Gaussian + Newton–Schulz polar projection.
pub fn random_point_complex<S: Scalar>(p: usize, n: usize, rng: &mut Rng) -> CMat<S> {
    assert!(p <= n, "St(p, n) needs p ≤ n, got ({p}, {n})");
    let g = CMat::<S>::randn(p, n, rng);
    polar_project(&g, PolarOpts { tol: 1e-9, max_iters: 100 })
}

/// Frobenius distance to the manifold: `‖X Xᵀ − I‖_F` (f32 convenience).
///
/// This is the feasibility metric of every figure in the paper ("manifold
/// distance").
pub fn distance(x: &Mat<f32>) -> f64 {
    distance_t(x)
}

/// `‖X Xᴴ − I‖_F` over any field — the one distance both manifolds share
/// (real: `X Xᵀ`; complex: `X Xᴴ`). Used by the field-generic
/// `ParamStore`.
pub fn distance_f<E: Field>(x: &Mat<E>) -> f64 {
    let mut g = matmul_a_bh(x, x);
    g.sub_eye_inplace();
    g.norm().to_f64()
}

/// `‖X Xᵀ − I‖_F`, generic in real precision.
pub fn distance_t<S: Scalar>(x: &Mat<S>) -> f64 {
    distance_f(x)
}

/// Dimension-invariant ("normalized") distance `‖X Xᴴ − I‖_F / √p`,
/// used by Fig. 6 to compare feasibility across matrix sizes. Defined
/// over any field, like [`distance_f`].
pub fn normalized_distance<E: Field>(x: &Mat<E>) -> f64 {
    distance_f(x) / (x.rows() as f64).sqrt()
}

/// The squared-distance potential `N(X) = ¼ ‖X Xᵀ − I‖²`.
pub fn potential<S: Scalar>(x: &Mat<S>) -> f64 {
    let d = distance_t(x);
    0.25 * d * d
}

/// Gradient of the potential: `∇N(X) = (X Xᵀ − I) X` — Landing's
/// manifold-attraction direction.
pub fn potential_grad<S: Scalar>(x: &Mat<S>) -> Mat<S> {
    let mut g = matmul_a_bt(x, x);
    g.sub_eye_inplace();
    matmul(&g, x)
}

/// Relative gradient `S = Skew(Xᵀ G)` (n×n skew-symmetric).
pub fn relative_gradient<S: Scalar>(x: &Mat<S>, g: &Mat<S>) -> Mat<S> {
    matmul_at_b(x, g).skew()
}

/// Riemannian gradient under the Euclidean metric: `X S ∈ T_X` (p×n).
pub fn riemannian_gradient<S: Scalar>(x: &Mat<S>, g: &Mat<S>) -> Mat<S> {
    matmul(x, &relative_gradient(x, g))
}

/// Project onto the manifold (closest point / polar factor).
pub fn project<S: Scalar>(x: &Mat<S>) -> Mat<S> {
    polar_project(x, PolarOpts::default())
}

/// Complex manifold distance `‖X Xᴴ − I‖_F`.
pub fn distance_complex<S: Scalar>(x: &CMat<S>) -> f64 {
    distance_f(x)
}

/// Complex relative gradient `S = SkewH(Xᴴ G)` and Riemannian gradient
/// `X S` for the unitary experiments.
pub fn riemannian_gradient_complex<S: Scalar>(x: &CMat<S>, g: &CMat<S>) -> CMat<S> {
    let s = matmul_ah_b(x, g).skew_h();
    matmul(x, &s)
}

/// Complex potential gradient `(X Xᴴ − I) X`.
pub fn potential_grad_complex<S: Scalar>(x: &CMat<S>) -> CMat<S> {
    let mut g = matmul_a_bh(x, x);
    g.sub_eye_inplace();
    matmul(&g, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_point_on_manifold() {
        let mut rng = Rng::seed_from_u64(0);
        for &(p, n) in &[(1, 1), (3, 3), (5, 16), (32, 64)] {
            let x = random_point_t::<f64>(p, n, &mut rng);
            assert!(distance_t(&x) < 1e-9, "({p},{n}): {}", distance_t(&x));
        }
    }

    #[test]
    fn riemannian_gradient_in_tangent_space() {
        // A ∈ T_X iff A = X S with S skew; equivalently X Aᵀ + A Xᵀ = 0.
        let mut rng = Rng::seed_from_u64(1);
        let x = random_point_t::<f64>(6, 14, &mut rng);
        let g = Mat::<f64>::randn(6, 14, &mut rng);
        let rg = riemannian_gradient(&x, &g);
        let constraint = matmul_a_bt(&x, &rg).add(&matmul_a_bt(&rg, &x));
        assert!(constraint.max_abs() < 1e-10);
    }

    #[test]
    fn tangent_and_normal_orthogonal() {
        // The paper's Fig. 2 geometry: grad f ⊥ ∇N at any X (even off the
        // manifold, ⟨X S, (X Xᵀ − I) X⟩ = Tr(Sᵀ Xᵀ (XXᵀ−I) X) = 0 because
        // Xᵀ(XXᵀ−I)X is symmetric and S is skew).
        let mut rng = Rng::seed_from_u64(2);
        let x0 = Mat::<f64>::randn(5, 11, &mut rng); // generic, off-manifold
        let g = Mat::<f64>::randn(5, 11, &mut rng);
        let rg = riemannian_gradient(&x0, &g);
        let ng = potential_grad(&x0);
        let inner = rg.dot(&ng).abs();
        assert!(inner < 1e-9, "⟨grad, ∇N⟩ = {inner}");
    }

    #[test]
    fn potential_grad_zero_on_manifold() {
        let mut rng = Rng::seed_from_u64(3);
        let x = random_point_t::<f64>(4, 9, &mut rng);
        assert!(potential_grad(&x).max_abs() < 1e-9);
        assert!(potential(&x) < 1e-18);
    }

    #[test]
    fn project_recovers_nearby_point() {
        let mut rng = Rng::seed_from_u64(4);
        let x = random_point_t::<f64>(4, 10, &mut rng);
        let noisy = x.add(&Mat::randn(4, 10, &mut rng).scale(1e-4));
        let back = project(&noisy);
        assert!(distance_t(&back) < 1e-6);
        assert!(back.sub(&x).norm() < 1e-3);
    }

    #[test]
    fn complex_random_point_unitary() {
        let mut rng = Rng::seed_from_u64(5);
        let x = random_point_complex::<f64>(3, 8, &mut rng);
        assert!(distance_complex(&x) < 1e-7);
    }

    #[test]
    fn complex_riemannian_gradient_tangency() {
        // X A^H + A X^H = 0 for A ∈ T_X of the complex Stiefel manifold.
        let mut rng = Rng::seed_from_u64(6);
        let x = random_point_complex::<f64>(4, 9, &mut rng);
        let g = CMat::<f64>::randn(4, 9, &mut rng);
        let rg = riemannian_gradient_complex(&x, &g);
        let c = matmul_a_bh(&x, &rg).add(&matmul_a_bh(&rg, &x));
        assert!(c.norm() < 1e-9);
    }

    #[test]
    fn normalized_distance_scale() {
        let mut x = Mat::<f64>::eye(8);
        x.scale_inplace(2.0); // X Xᵀ = 4 I ⇒ ‖XXᵀ − I‖ = 3√8
        let d = distance_t(&x);
        assert!((d - 3.0 * (8.0f64).sqrt()).abs() < 1e-9);
        assert!((normalized_distance(&x) - 3.0).abs() < 1e-9);
    }
}
