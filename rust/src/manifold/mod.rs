//! Manifold geometry helpers.

pub mod stiefel;
