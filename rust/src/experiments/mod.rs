//! Experiment drivers — one module per paper figure (see DESIGN.md §5 for
//! the index). Each driver runs the paper's method lineup on the workload,
//! logs wall-clock series (loss / optimality gap / manifold distance) and
//! writes the CSVs that regenerate the figure.

pub mod born;
pub mod cnn;
pub mod common;
pub mod lambda_ablation;
pub mod ovit;
pub mod pca;
pub mod precision;
pub mod procrustes;
pub mod scale;

use crate::config::{ExperimentId, RunConfig};
use anyhow::Result;

/// Dispatch an experiment by id.
pub fn run(cfg: &RunConfig) -> Result<()> {
    match cfg.experiment {
        ExperimentId::Fig4Pca => pca::run(cfg),
        ExperimentId::Fig4Procrustes => procrustes::run(cfg),
        ExperimentId::Fig5Ovit => ovit::run(cfg),
        ExperimentId::Fig1CnnFilters => cnn::run(cfg, cnn::Parameterization::Filters),
        ExperimentId::Fig1CnnKernels => cnn::run(cfg, cnn::Parameterization::Kernels),
        ExperimentId::Fig8Born => born::run(cfg),
        ExperimentId::FigC1Precision => precision::run(cfg),
        ExperimentId::FigC2Lambda => lambda_ablation::run(cfg),
        ExperimentId::ScaleMatrices => scale::run(cfg),
    }
}
