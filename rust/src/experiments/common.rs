//! Shared experiment plumbing: engine selection, CSV emission, summary
//! tables, and the per-method run record.

use crate::config::RunConfig;
use crate::coordinator::{MetricLog, OptimizerSpec};
use crate::optim::{Engine, Method};
use crate::runtime::Registry;
use anyhow::Result;
use std::path::PathBuf;

/// Default engine assignment — the paper's systems claim: matmul-only
/// methods run as AOT accelerator programs, retraction methods on host.
pub fn engine_for(method: Method) -> Engine {
    if method.is_matmul_only() {
        Engine::Xla
    } else {
        Engine::Rust
    }
}

/// Replace a spec's engine with the method's default assignment.
pub fn with_default_engine(spec: OptimizerSpec) -> OptimizerSpec {
    let e = engine_for(spec.method);
    spec.with_engine(e)
}

/// Engine selection for a driver: an explicit `--spec` replay pins its
/// own engine; paper presets get the default assignment, except under
/// `--quick` (tiny smoke shapes have no AOT artifacts, so quick runs
/// use the Rust engine everywhere).
pub fn with_engine_for(cfg: &RunConfig, spec: OptimizerSpec) -> OptimizerSpec {
    if cfg.spec.is_some_and(|s| s.method == spec.method) {
        return spec;
    }
    if cfg.quick {
        spec.with_engine(Engine::Rust)
    } else {
        with_default_engine(spec)
    }
}

/// Open the artifact registry, with a helpful error.
pub fn open_registry() -> Result<Registry> {
    Registry::open_default().map_err(|e| {
        anyhow::anyhow!("{e}\nhint: run `make artifacts` to build the AOT programs first")
    })
}

/// One method's finished run.
pub struct RunRecord {
    pub method: Method,
    pub label: String,
    pub log: MetricLog,
    pub wall_s: f64,
    /// The exact spec the run used; emitted as a replayable
    /// `*.spec.json` manifest next to the CSV (`pogo run --spec` input).
    pub spec: Option<OptimizerSpec>,
}

/// CSV path for a run: `<out>/<experiment>_<label>_rep<k>.csv`.
pub fn csv_path(cfg: &RunConfig, label: &str, rep: usize) -> PathBuf {
    let safe: String = label
        .chars()
        .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect();
    cfg.out_dir.join(format!("{}_{safe}_rep{rep}.csv", cfg.experiment.name()))
}

/// Write a run's CSV (plus its replayable spec manifest) and log the
/// location.
pub fn emit(cfg: &RunConfig, rec: &RunRecord, rep: usize) -> Result<()> {
    let path = csv_path(cfg, &rec.label, rep);
    rec.log.write_csv(&path)?;
    if let Some(spec) = &rec.spec {
        spec.write_json_file(&path.with_extension("spec.json"))?;
    }
    log::debug!("wrote {}", path.display());
    Ok(())
}

/// Print the end-of-experiment summary table (the "who wins by what
/// factor" shape the paper's figures encode).
pub fn print_summary(title: &str, records: &[RunRecord], metrics: &[&str]) {
    println!("\n== {title} ==");
    print!("{:<22} {:>9}", "method", "time");
    for m in metrics {
        print!(" {:>14}", m);
    }
    println!();
    for r in records {
        print!("{:<22} {:>9}", r.label, crate::util::fmt_duration(r.wall_s));
        for m in metrics {
            let v = match *m {
                // "final/..." = last value, "best/..." = min value.
                s if s.starts_with("best/") => r.log.min(&s[5..]),
                s if s.starts_with("max/") => r.log.max(&s[4..]),
                s => r.log.last(s),
            };
            match v {
                Some(v) if v.abs() < 1e-3 || v.abs() >= 1e4 => print!(" {v:>14.3e}"),
                Some(v) => print!(" {v:>14.4}"),
                None => print!(" {:>14}", "-"),
            }
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentId;

    #[test]
    fn engines_follow_matmul_rule() {
        assert_eq!(engine_for(Method::Pogo), Engine::Xla);
        assert_eq!(engine_for(Method::Slpg), Engine::Xla);
        assert_eq!(engine_for(Method::Rgd), Engine::Rust);
        assert_eq!(engine_for(Method::Adam), Engine::Rust);
    }

    #[test]
    fn spec_override_pins_its_engine() {
        let mut cfg = RunConfig::new(ExperimentId::Fig4Pca);
        // Preset path: matmul-only methods get the XLA default.
        let preset = crate::config::resolve_spec(&cfg, Method::Pogo);
        assert_eq!(with_engine_for(&cfg, preset).engine, Engine::Xla);
        // Replay path: an explicit --spec keeps its requested engine.
        cfg.spec = Some(OptimizerSpec::new(Method::Pogo, 0.1)); // engine Rust
        let replayed = crate::config::resolve_spec(&cfg, Method::Pogo);
        assert_eq!(with_engine_for(&cfg, replayed).engine, Engine::Rust);
        // Other methods in the lineup still get defaults.
        let other = crate::config::resolve_spec(&cfg, Method::Rgd);
        assert_eq!(with_engine_for(&cfg, other).engine, Engine::Rust);
        let slpg = crate::config::resolve_spec(&cfg, Method::Slpg);
        assert_eq!(with_engine_for(&cfg, slpg).engine, Engine::Xla);
    }

    #[test]
    fn csv_paths_are_sanitized() {
        let cfg = RunConfig::new(ExperimentId::Fig4Pca);
        let p = csv_path(&cfg, "POGO(vadam)[xla]", 2);
        let s = p.file_name().unwrap().to_str().unwrap();
        assert_eq!(s, "fig4-pca_pogo_vadam__xla__rep2.csv");
    }

    #[test]
    fn emit_writes_replayable_spec_manifest() {
        let mut cfg = RunConfig::new(ExperimentId::Fig4Pca);
        cfg.out_dir =
            std::env::temp_dir().join(format!("pogo_emit_test_{}", std::process::id()));
        let mut log = MetricLog::new("t");
        log.record(0, &[("loss", 1.0)]);
        let spec = OptimizerSpec::new(Method::Pogo, 0.1)
            .with_base(crate::optim::base::BaseOptKind::vadam());
        let rec = RunRecord {
            method: Method::Pogo,
            label: "POGO".to_string(),
            log,
            wall_s: 0.0,
            spec: Some(spec),
        };
        emit(&cfg, &rec, 0).unwrap();
        let manifest = csv_path(&cfg, &rec.label, 0).with_extension("spec.json");
        let back = OptimizerSpec::from_json_file(&manifest).unwrap();
        assert_eq!(back, spec);
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
