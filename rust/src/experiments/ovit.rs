//! Fig. 5: O-ViT — vision transformer with 18 orthogonal matrices.
//!
//! The 18 square (128, 128) attention/MLP matrices form ONE batched group
//! (`pogo_step_b18_128x128` etc.); patch/positional embeddings and the
//! head train with Adam. Matches the paper's observation target: similar
//! final accuracy across orthoptimizers, big differences in wall time and
//! manifold distance.

use super::common::{self, RunRecord};
use crate::config::{resolve_spec, RunConfig};
use crate::coordinator::{ParamStore, Trainer, TrainerConfig};
use crate::data::cifar_like::CifarLike;
use crate::linalg::MatF;
use crate::optim::Method;
use crate::rng::Rng;
use crate::runtime::{Arg, Registry};
use anyhow::Result;
use std::rc::Rc;

/// Mirrors python/compile/models/vit.py.
pub const N_ORTH: usize = 18;
pub const DIM: usize = 128;
pub const PATCH_W: (usize, usize) = (48, DIM);
pub const POS: (usize, usize) = (64, DIM);
pub const HEAD: (usize, usize) = (DIM, 10);
pub const TRAIN_BATCH: usize = 32;
pub const EVAL_BATCH: usize = 128;

fn build_store(constrained: bool, rng: &mut Rng) -> ParamStore {
    let mut store = ParamStore::new();
    for i in 0..N_ORTH {
        let x = crate::manifold::stiefel::random_point(DIM, DIM, rng);
        if constrained {
            store.add_stiefel_keyed(format!("orth_{i}"), x, "orth");
        } else {
            store.add_free(format!("orth_{i}"), x);
        }
    }
    store.add_free("patch_w", MatF::randn(PATCH_W.0, PATCH_W.1, rng).scale(0.05));
    store.add_free("pos", MatF::randn(POS.0, POS.1, rng).scale(0.02));
    store.add_free("head", MatF::randn(HEAD.0, HEAD.1, rng).scale(0.05));
    store
}

struct VitGrads {
    lossgrad: Rc<crate::runtime::Executable>,
    eval: Rc<crate::runtime::Executable>,
    data: CifarLike,
    eval_images: Vec<f32>,
    eval_labels: Vec<i32>,
}

impl VitGrads {
    fn new(reg: &Registry, seed: u64) -> Result<VitGrads> {
        let mut data = CifarLike::new(seed, 0.15);
        let (eval_images, eval_labels) = data.batch(EVAL_BATCH);
        Ok(VitGrads {
            lossgrad: reg.get("vit_lossgrad")?,
            eval: reg.get("vit_eval")?,
            data,
            eval_images,
            eval_labels,
        })
    }

    fn pack_params<'a>(&self, store: &'a ParamStore) -> Result<Vec<f32>> {
        let orth: Vec<MatF> = (0..N_ORTH).map(|i| store.mat(i).clone()).collect();
        crate::runtime::pack_batch(&orth)
    }

    fn eval_step(&mut self, store: &ParamStore) -> Result<(f64, Vec<MatF>)> {
        let orth = self.pack_params(store)?;
        let (images, labels) = self.data.batch(TRAIN_BATCH);
        let outs = self.lossgrad.run(&[
            Arg::F32(&orth, vec![N_ORTH, DIM, DIM]),
            Arg::Mat(store.mat(N_ORTH)),
            Arg::Mat(store.mat(N_ORTH + 1)),
            Arg::Mat(store.mat(N_ORTH + 2)),
            Arg::F32(&images, vec![TRAIN_BATCH, 32, 32, 3]),
            Arg::I32(&labels, vec![TRAIN_BATCH]),
        ])?;
        let loss = crate::runtime::literal_to_scalar(&outs[0])? as f64;
        let g_orth = crate::runtime::literal_to_vec(&outs[1])?;
        let mut grads: Vec<MatF> = Vec::with_capacity(store.len());
        let per = DIM * DIM;
        for i in 0..N_ORTH {
            grads.push(MatF::from_vec(DIM, DIM, g_orth[i * per..(i + 1) * per].to_vec()));
        }
        grads.push(crate::runtime::literal_to_mat(&outs[2], PATCH_W.0, PATCH_W.1)?);
        grads.push(crate::runtime::literal_to_mat(&outs[3], POS.0, POS.1)?);
        grads.push(crate::runtime::literal_to_mat(&outs[4], HEAD.0, HEAD.1)?);
        Ok((loss, grads))
    }

    fn test_metrics(&self, store: &ParamStore) -> Result<(f64, f64)> {
        let orth = self.pack_params(store)?;
        let outs = self.eval.run(&[
            Arg::F32(&orth, vec![N_ORTH, DIM, DIM]),
            Arg::Mat(store.mat(N_ORTH)),
            Arg::Mat(store.mat(N_ORTH + 1)),
            Arg::Mat(store.mat(N_ORTH + 2)),
            Arg::F32(&self.eval_images, vec![EVAL_BATCH, 32, 32, 3]),
            Arg::I32(&self.eval_labels, vec![EVAL_BATCH]),
        ])?;
        let loss = crate::runtime::literal_to_scalar(&outs[0])? as f64;
        let acc = crate::runtime::literal_to_scalar(&outs[1])? as f64;
        Ok((loss, acc))
    }
}

/// Run the Fig. 5 experiment.
pub fn run(cfg: &RunConfig) -> Result<()> {
    let reg = common::open_registry()?;
    let steps = if cfg.quick { 4 } else { cfg.steps };
    let eval_every = (steps / 10).max(1);
    let mut records = Vec::new();

    for rep in 0..cfg.repetitions {
        for &method in &cfg.methods {
            let mut rng = Rng::seed_from_u64(cfg.seed + 13 * rep as u64);
            let constrained = method != Method::Adam;
            let store = build_store(constrained, &mut rng);
            let spec = common::with_engine_for(cfg, resolve_spec(cfg, method));
            let mut grads = VitGrads::new(&reg, cfg.seed + rep as u64)?;
            let mut tr = Trainer::new(
                store,
                spec,
                Some(&reg),
                TrainerConfig {
                    max_steps: steps,
                    log_every: eval_every,
                    free_lr: 3e-3,
                    ..Default::default()
                },
            )?;

            for s in 0..steps {
                let loss = {
                    let g = &mut grads;
                    let mut src = |store: &ParamStore| g.eval_step(store);
                    tr.step(&mut src)?
                };
                if s % eval_every == 0 || s + 1 == steps {
                    let (test_loss, acc) = grads.test_metrics(&tr.store)?;
                    let d = tr.store.max_stiefel_distance();
                    tr.log.record(tr.step_idx(), &[
                        ("loss", loss),
                        ("test_loss", test_loss),
                        ("test_acc", acc),
                        ("distance", d),
                    ]);
                    log::info!(
                        "{} step {s}: loss {loss:.3} acc {acc:.3} dist {d:.2e}",
                        spec.label()
                    );
                }
            }
            let wall = tr.log.elapsed();
            let rec = RunRecord {
                method,
                label: spec.label(),
                log: tr.log,
                wall_s: wall,
                spec: Some(spec),
            };
            common::emit(cfg, &rec, rep)?;
            records.push(rec);
        }
    }

    common::print_summary(
        "Fig. 5 — O-ViT (18 orthogonal 128×128 matrices)",
        &records,
        &["max/test_acc", "distance"],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_has_one_orth_group_of_18() {
        let mut rng = Rng::seed_from_u64(0);
        let s = build_store(true, &mut rng);
        let groups = s.stiefel_groups();
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].indices.len(), N_ORTH);
        assert_eq!(groups[0].shape, (DIM, DIM));
        assert_eq!(s.free_indices().len(), 3);
    }
}
