//! Fig. 4 (left): online PCA — find the top-p eigenspace of `A Aᵀ`.
//!
//! `max ‖X A‖² s.t. X ∈ St(p, n)` (Eq. 14). Following §5.1, `A Aᵀ` is PSD
//! with condition number 1000 and exponentially decaying spectrum, built
//! from a *known* spectrum so the analytic optimum (sum of the top-p
//! eigenvalues) is exact — the optimality-gap series needs no eigensolve.
//!
//! Loss convention here: f(X) = −‖X A‖² (minimized); the gap is
//! `(f − f*) / |f*|`. Early stop at gap ≤ 1e-6 as in the paper.

use super::common::{self, RunRecord};
use crate::config::{resolve_spec, RunConfig};
use crate::coordinator::{ParamStore, Trainer, TrainerConfig};
use crate::linalg::{matmul, with_spectrum, Mat, MatD, MatF};
use crate::manifold::stiefel;
use crate::rng::Rng;
use crate::runtime::{Arg, Registry};
use anyhow::Result;

/// Problem instance: AAT (n×n), analytic optimal loss, shapes.
pub struct PcaProblem {
    pub aat: MatF,
    pub p: usize,
    pub n: usize,
    pub optimal_loss: f64,
}

/// Build the §5.1 instance: spectrum w_i = exp(−α i) scaled to κ = 1000.
pub fn build_problem(p: usize, n: usize, rng: &mut Rng) -> PcaProblem {
    let kappa: f64 = 1000.0;
    let alpha = kappa.ln() / (n as f64 - 1.0);
    let spectrum: Vec<f64> = (0..n).map(|i| (-alpha * i as f64).exp()).collect();
    // Construct in f64 for an accurate eigenbasis, then cast.
    let aat_d: MatD = with_spectrum(&spectrum, rng);
    let optimal_loss = -spectrum.iter().take(p).sum::<f64>();
    PcaProblem { aat: aat_d.cast(), p, n, optimal_loss }
}

/// Optimality gap of a loss value.
pub fn gap(problem: &PcaProblem, loss: f64) -> f64 {
    (loss - problem.optimal_loss) / problem.optimal_loss.abs()
}

/// Gradient source backed by the AOT `pca_lossgrad` program (shared by all
/// methods so the comparison isolates the optimizer).
pub struct PcaGrads<'r> {
    exe: std::rc::Rc<crate::runtime::Executable>,
    problem: &'r PcaProblem,
}

impl<'r> PcaGrads<'r> {
    pub fn new(reg: &Registry, problem: &'r PcaProblem) -> Result<Self> {
        let name = format!("pca_lossgrad_{}x{}", problem.p, problem.n);
        Ok(PcaGrads { exe: reg.get(&name)?, problem })
    }

    pub fn eval_one(&self, x: &MatF) -> Result<(f64, MatF)> {
        let outs = self.exe.run(&[Arg::Mat(x), Arg::Mat(&self.problem.aat)])?;
        let loss = crate::runtime::literal_to_scalar(&outs[0])? as f64;
        let grad = crate::runtime::literal_to_mat(&outs[1], self.problem.p, self.problem.n)?;
        Ok((loss, grad))
    }
}

/// Pure-Rust gradient (used by the precision ablation and as fallback):
/// f = −Tr(X AAT Xᵀ), ∇f = −2 X AAT.
pub fn lossgrad_rust<S: crate::linalg::Scalar>(x: &Mat<S>, aat: &Mat<S>) -> (f64, Mat<S>) {
    let xa = matmul(x, aat);
    let loss = -xa.dot(x).to_f64();
    (loss, xa.scale(S::from_f64(-2.0)))
}

/// Run the Fig. 4 PCA comparison.
pub fn run(cfg: &RunConfig) -> Result<()> {
    let reg = common::open_registry()?;
    let (p, n) = if cfg.full { (1500, 2000) } else { (300, 400) };
    let (p, n) = if cfg.quick { (30, 40) } else { (p, n) };
    let mut records = Vec::new();

    for rep in 0..cfg.repetitions {
        let mut rng = Rng::seed_from_u64(cfg.seed + rep as u64);
        let problem = build_problem(p, n, &mut rng);
        let x0 = stiefel::random_point(p, n, &mut rng);

        for &method in &cfg.methods {
            let spec = common::with_engine_for(cfg, resolve_spec(cfg, method));
            let mut store = ParamStore::new();
            store.add_stiefel("x", x0.clone());
            let mut tr = Trainer::new(
                store,
                spec,
                Some(&reg),
                TrainerConfig {
                    max_steps: cfg.steps,
                    log_every: 1,
                    ..Default::default()
                },
            )?;
            let grads = if cfg.quick {
                None // tiny shapes have no artifacts; use the Rust path
            } else {
                Some(PcaGrads::new(&reg, &problem)?)
            };
            // §Perf: probe feasibility through the XLA distance program
            // (~2 ms) instead of a host gram (~15 ms at this shape).
            let dist_exe =
                if cfg.quick { None } else { Some(reg.get(&format!("distance_b1_{p}x{n}"))?) };

            let mut last_gap = f64::INFINITY;
            for _ in 0..cfg.steps {
                let aat = problem.aat.clone();
                let loss = match &grads {
                    Some(g) => {
                        let gref = g;
                        let mut src = |store: &ParamStore| {
                            let (l, gr) = gref.eval_one(store.mat(0))?;
                            Ok((l, vec![gr]))
                        };
                        tr.step(&mut src)?
                    }
                    None => {
                        let mut src = move |store: &ParamStore| {
                            let (l, gr) = lossgrad_rust(store.mat(0), &aat);
                            Ok((l, vec![gr]))
                        };
                        tr.step(&mut src)?
                    }
                };
                last_gap = gap(&problem, loss);
                let d = match &dist_exe {
                    Some(exe) => {
                        let xs = [tr.store.mat(0).clone()];
                        let outs = exe.run(&[Arg::Batch(&xs)])?;
                        crate::runtime::literal_to_scalar(&outs[0])? as f64
                    }
                    None => stiefel::distance(tr.store.mat(0)),
                };
                tr.log.record(tr.step_idx(), &[
                    ("loss", loss),
                    ("gap", last_gap.max(1e-12)),
                    ("distance", d),
                ]);
                if last_gap <= 1e-6 {
                    break; // paper's early-stop criterion
                }
            }
            let wall = tr.log.elapsed();
            log::info!(
                "{}: gap {:.2e} in {} ({} steps)",
                spec.label(),
                last_gap,
                crate::util::fmt_duration(wall),
                tr.step_idx()
            );
            let rec = RunRecord {
                method,
                label: spec.label(),
                log: tr.log,
                wall_s: wall,
                spec: Some(spec),
            };
            common::emit(cfg, &rec, rep)?;
            records.push(rec);
        }
    }

    common::print_summary(
        &format!("Fig. 4 — online PCA (p={p}, n={n})"),
        &records,
        &["best/gap", "distance"],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_spectrum_and_optimum() {
        let mut rng = Rng::seed_from_u64(0);
        let prob = build_problem(5, 20, &mut rng);
        // Optimal loss is −(sum of top 5 of the exp-decaying spectrum).
        assert!(prob.optimal_loss < 0.0);
        assert!(prob.optimal_loss > -5.0);
        // AAT symmetric PSD: x' AAT x ≥ 0 on a probe.
        let v = MatF::randn(1, 20, &mut rng);
        let q = matmul(&matmul(&v, &prob.aat), &v.transpose())[(0, 0)];
        assert!(q >= -1e-3, "not PSD: {q}");
    }

    #[test]
    fn rust_lossgrad_matches_finite_differences() {
        let mut rng = Rng::seed_from_u64(1);
        let prob = build_problem(3, 8, &mut rng);
        let aat: MatD = prob.aat.cast();
        let x: MatD = stiefel::random_point(3, 8, &mut rng).cast();
        let (l0, g) = lossgrad_rust(&x, &aat);
        let eps = 1e-5;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (2, 7)] {
            let mut xp = x.clone();
            xp[(i, j)] += eps;
            let (l1, _) = lossgrad_rust(&xp, &aat);
            let fd = (l1 - l0) / eps;
            assert!(
                (fd - g[(i, j)]).abs() < 1e-2 * (1.0 + fd.abs()),
                "({i},{j}): fd {fd} vs {}",
                g[(i, j)]
            );
        }
    }

    #[test]
    fn pogo_closes_gap_on_small_instance() {
        // Small end-to-end: POGO(momentum) reaches a small gap quickly.
        let mut rng = Rng::seed_from_u64(2);
        let prob = build_problem(8, 24, &mut rng);
        let mut x = stiefel::random_point(8, 24, &mut rng);
        let mut opt = crate::optim::pogo::Pogo::<f32>::new(
            crate::optim::pogo::PogoConfig {
                lr: 0.25,
                base: crate::optim::base::BaseOptKind::momentum(0.3),
                ..Default::default()
            },
            1,
        );
        use crate::optim::Orthoptimizer;
        let mut g_final = f64::INFINITY;
        for _ in 0..400 {
            let (loss, grad) = lossgrad_rust(&x, &prob.aat);
            opt.step(0, &mut x, &grad).unwrap();
            g_final = gap(&prob, loss);
        }
        assert!(g_final < 0.05, "gap {g_final}");
        assert!(stiefel::distance(&x) < 1e-2);
    }
}
