//! Fig. C.2/C.3: the λ-policy ablation — solve the landing quartic vs fix
//! λ = 1/2, across learning rates, POGO with no base optimizer.
//!
//! Expected shape (paper §C.6): at small η the two policies are
//! indistinguishable; as η grows, λ = 1/2 first fluctuates then *diverges*
//! (ξ < 1 violated), while the root-solved λ survives higher η. POGO with
//! VAdam is plotted as the reference that sidesteps the whole trade-off.
//! Runs on Procrustes (fast, exact optimum) rather than the PC benchmark;
//! the same sweep on Born-MPS is in `benches/ablations.rs`.

use super::common::{self, RunRecord};
use super::procrustes::{self, ProcrustesProblem};
use crate::config::RunConfig;
use crate::coordinator::{MetricLog, OptimizerSpec};
use crate::linalg::MatF;
use crate::manifold::stiefel;
use crate::optim::base::BaseOptKind;
use crate::optim::pogo::LambdaPolicy;
use crate::optim::Method;
use crate::rng::Rng;
use anyhow::Result;

/// The §C.6 learning-rate grid (scaled to our problem size). The top end
/// deliberately crosses the ξ < 1 boundary so the λ = 1/2 divergence —
/// "every other version not appearing in the plot diverged within the
/// first epoch" — is observable.
pub const LR_GRID: [f64; 5] = [1e-5, 1e-4, 1e-3, 5e-3, 2e-2];

/// The spec for one ablation cell (also emitted as its replay manifest).
fn cell_spec(lr: f64, policy: LambdaPolicy, base: BaseOptKind) -> OptimizerSpec {
    OptimizerSpec::new(Method::Pogo, lr).with_lambda(policy).with_base(base)
}

fn run_one(
    problem: &ProcrustesProblem,
    x0: &MatF,
    spec: &OptimizerSpec,
    steps: usize,
) -> Result<MetricLog> {
    let pol = match spec.lambda {
        LambdaPolicy::Half => "half",
        LambdaPolicy::FindRoot => "root",
    };
    let lr = spec.lr;
    let label = match spec.base {
        BaseOptKind::Sgd => format!("POGO-{pol}(lr={lr:.0e})"),
        _ => format!("POGO-vadam-{pol}(lr={lr:.0e})"),
    };
    let mut log = MetricLog::new(label);
    let mut x = x0.clone();
    let mut opt = spec.build::<f32>(None, (1, x0.rows(), x0.cols()))?;
    for s in 0..steps {
        let (loss, grad) = procrustes::lossgrad_rust(&x, problem);
        if !loss.is_finite() || !x.all_finite() {
            // Divergence: record a sentinel and stop (the paper notes the
            // λ=1/2 high-lr runs "diverged within the first epoch").
            log.record(s, &[("gap", f64::INFINITY), ("distance", f64::INFINITY),
                            ("diverged", 1.0)]);
            break;
        }
        opt.step(0, &mut x, &grad)?;
        if s % 5 == 0 || s + 1 == steps {
            let d = stiefel::distance(&x);
            log.record(s, &[
                ("gap", procrustes::gap(problem, loss).max(1e-12)),
                ("distance", d.max(1e-14)),
                ("lambda", opt.last_lambda().unwrap_or(0.5)),
            ]);
        }
    }
    Ok(log)
}

/// Run the λ ablation.
pub fn run(cfg: &RunConfig) -> Result<()> {
    let n = if cfg.quick { 24 } else { 128 };
    let steps = if cfg.quick { 40 } else { cfg.steps };
    let mut records = Vec::new();

    for rep in 0..cfg.repetitions {
        let mut rng = Rng::seed_from_u64(cfg.seed + rep as u64);
        let problem = procrustes::build_problem(n, &mut rng);
        let x0 = stiefel::random_point(n, n, &mut rng);

        for &lr in &LR_GRID {
            for policy in [LambdaPolicy::FindRoot, LambdaPolicy::Half] {
                let spec = cell_spec(lr, policy, BaseOptKind::Sgd);
                let log = run_one(&problem, &x0, &spec, steps)?;
                let wall = log.elapsed();
                let diverged = log.last("diverged").is_some();
                log::info!(
                    "{}: {} (dist {:.2e})",
                    log.label,
                    if diverged { "DIVERGED" } else { "ok" },
                    log.last("distance").unwrap_or(f64::NAN)
                );
                let rec = RunRecord {
                    method: Method::Pogo,
                    label: log.label.clone(),
                    log,
                    wall_s: wall,
                    spec: Some(spec),
                };
                common::emit(cfg, &rec, rep)?;
                records.push(rec);
            }
        }
        // VAdam reference (the §C.6 plots' extra line).
        let spec = cell_spec(0.5, LambdaPolicy::Half, BaseOptKind::vadam());
        let log = run_one(&problem, &x0, &spec, steps)?;
        let wall = log.elapsed();
        let rec = RunRecord {
            method: Method::Pogo,
            label: log.label.clone(),
            log,
            wall_s: wall,
            spec: Some(spec),
        };
        common::emit(cfg, &rec, rep)?;
        records.push(rec);
    }

    common::print_summary(
        &format!("Fig. C.2/C.3 — λ policy × lr (Procrustes n={n})"),
        &records,
        &["best/gap", "distance"],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_lr_policies_indistinguishable() {
        // §C.6: "no difference at all between fixing λ or computing the
        // root for the smallest learning rate".
        let mut rng = Rng::seed_from_u64(0);
        let problem = procrustes::build_problem(16, &mut rng);
        let x0 = stiefel::random_point(16, 16, &mut rng);
        let half =
            run_one(&problem, &x0, &cell_spec(1e-5, LambdaPolicy::Half, BaseOptKind::Sgd), 60)
                .unwrap();
        let root = run_one(
            &problem,
            &x0,
            &cell_spec(1e-5, LambdaPolicy::FindRoot, BaseOptKind::Sgd),
            60,
        )
        .unwrap();
        let gh = half.last("gap").unwrap();
        let gr = root.last("gap").unwrap();
        // Same descent to within a few percent, and both feasible.
        assert!((gh - gr).abs() < 0.1 * (1.0 + gh.abs()), "{gh} vs {gr}");
        assert!(half.last("distance").unwrap() < 1e-3);
        assert!(root.last("distance").unwrap() < 1e-3);
    }

    #[test]
    fn root_survives_higher_lr_than_half() {
        // At an aggressive lr, λ=1/2 must do no better (and typically
        // diverges or drifts) compared to the root-solved policy.
        let mut rng = Rng::seed_from_u64(1);
        let problem = procrustes::build_problem(16, &mut rng);
        let x0 = stiefel::random_point(16, 16, &mut rng);
        let big = 0.05; // far beyond ξ<1 for this problem's gradients
        let half =
            run_one(&problem, &x0, &cell_spec(big, LambdaPolicy::Half, BaseOptKind::Sgd), 80)
                .unwrap();
        let root = run_one(
            &problem,
            &x0,
            &cell_spec(big, LambdaPolicy::FindRoot, BaseOptKind::Sgd),
            80,
        )
        .unwrap();
        let dh = half.last("distance").unwrap_or(f64::INFINITY);
        let dr = root.last("distance").unwrap_or(f64::INFINITY);
        assert!(
            dr <= dh * 10.0 || dh.is_infinite(),
            "root dist {dr} unexpectedly much worse than half {dh}"
        );
    }
}
