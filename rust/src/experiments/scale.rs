//! The scalability headline (Fig. 1's "3 minutes vs 17 hours"): per-step
//! cost as the number of orthogonal 3×3 matrices grows.
//!
//! Compares, at B ∈ {64, 512, 4096, 32768} matrices:
//! - **POGO[batched]** — the batched host engine: ONE `(B, 3, 3)` tensor
//!   stepped with batch-parallel kernels (`Engine::BatchedHost`);
//! - **POGO[loop]** — same math, sequential per-matrix host loop (a 3×3
//!   matmul never crosses the thread threshold, so the pool sits idle —
//!   exactly what this sweep quantifies);
//! - **POGO[xla]** — ONE batched AOT dispatch per step, when the artifact
//!   registry is available (`make artifacts`);
//! - **RGD** / **RSDM(r=2)** — per-matrix QR retraction baselines.
//!
//! Reports µs/matrix/step and the extrapolated wall time for the paper's
//! 218 624-kernel workload at 100 epochs — the Fig. 1 x-axis, regenerated.
//! Besides the usual CSVs, the sweep emits a machine-readable
//! `BENCH_scale.json` (see `bench::scale_json`) whose
//! `speedup_batched_vs_loop` map is the number CI's `bench-smoke` job
//! gates on.

use super::common::{self, RunRecord};
use crate::bench::ScaleRecord;
use crate::config::{resolve_spec, RunConfig};
use crate::coordinator::{MetricLog, OptimizerSpec};
use crate::linalg::MatF;
use crate::manifold::stiefel;
use crate::optim::{Engine, Method, Orthoptimizer};
use crate::rng::Rng;
use crate::util::Stopwatch;
use anyhow::Result;

pub const BATCHES: [usize; 4] = [64, 512, 4096, 32768];

/// Paper workload: kernels × steps for the extrapolated column.
pub const PAPER_KERNELS: usize = 218_624;
pub const PAPER_STEPS: usize = 9_800; // ≈100 epochs × 98 steps/epoch

/// Engine-contender labels (stable: `BENCH_scale.json` consumers key on
/// them).
pub const LABEL_LOOP: &str = "POGO[loop]";
pub const LABEL_BATCHED: &str = "POGO[batched]";
pub const LABEL_XLA: &str = "POGO[xla]";

/// The Fig. 1 workload generator: B random 3×3 Stiefel points plus
/// norm-0.5 gradients. Shared with `benches/step_micro.rs` so the
/// CI-gated benchmark measures exactly this sweep's workload.
pub fn make_group(b: usize, rng: &mut Rng) -> (Vec<MatF>, Vec<MatF>) {
    let xs: Vec<MatF> = (0..b).map(|_| stiefel::random_point(3, 3, rng)).collect();
    let gs: Vec<MatF> = (0..b)
        .map(|_| {
            let g = MatF::randn(3, 3, rng);
            let n = g.norm();
            g.scale(0.5 / n)
        })
        .collect();
    (xs, gs)
}

/// Time `steps` steps of one optimizer over the group; µs per matrix-step.
fn time_method(
    opt: &mut dyn Orthoptimizer<f32>,
    xs: &mut [MatF],
    gs: &[MatF],
    steps: usize,
) -> Result<f64> {
    let sw = Stopwatch::start();
    for _ in 0..steps {
        opt.step_group(xs, gs)?;
    }
    Ok(sw.seconds() * 1e6 / (steps as f64 * xs.len() as f64))
}

/// The engine contenders to run for `method`. POGO — the paper's
/// scalability mechanism — races its host loop against the batched host
/// engine (and the XLA engine when artifacts exist); every baseline runs
/// its usual single engine. An explicit `--spec` replay pins exactly the
/// engine it names.
fn contenders(cfg: &RunConfig, method: Method, has_registry: bool) -> Vec<(String, OptimizerSpec)> {
    if let Some(s) = cfg.spec {
        if s.method == method {
            return vec![(s.label(), s)];
        }
    }
    if method != Method::Pogo {
        let spec = resolve_spec(cfg, method);
        return vec![(spec.label(), spec)];
    }
    let preset = resolve_spec(cfg, Method::Pogo);
    let mut v = vec![
        (LABEL_LOOP.to_string(), preset.with_engine(Engine::Rust)),
        (LABEL_BATCHED.to_string(), preset.with_engine(Engine::BatchedHost)),
    ];
    if has_registry {
        v.push((LABEL_XLA.to_string(), preset.with_engine(Engine::Xla)));
    }
    v
}

/// Run the scalability sweep.
pub fn run(cfg: &RunConfig) -> Result<()> {
    // The registry is only needed by the XLA contender — the host engines
    // (loop + batched) must run anywhere, including CI's bench-smoke job,
    // which has no AOT artifacts.
    let reg = match common::open_registry() {
        Ok(r) => Some(r),
        Err(e) => {
            log::warn!("no artifact registry — skipping the XLA contender ({e:#})");
            None
        }
    };
    let steps = if cfg.quick { 3 } else { cfg.steps };
    let batches: &[usize] = if cfg.quick { &BATCHES[..3] } else { &BATCHES };
    let mut records = Vec::new();
    let mut bench_rows: Vec<ScaleRecord> = Vec::new();

    for &method in &cfg.methods {
        for (label, spec) in contenders(cfg, method, reg.is_some()) {
            let mut log = MetricLog::new(label.clone());
            for &b in batches {
                // Retraction baselines get prohibitively slow at large B;
                // subsample their step count to keep the sweep bounded, the
                // per-matrix metric is unaffected.
                let eff_steps = if method.is_matmul_only() { steps } else { steps.min(5) };
                let mut rng = Rng::seed_from_u64(cfg.seed + b as u64);
                let (mut xs, gs) = make_group(b, &mut rng);
                let mut opt = spec.build::<f32>(reg.as_ref(), (b, 3, 3))?;
                // Warm-up dispatch (compile cache, allocator, pool).
                opt.step_group(&mut xs, &gs)?;
                let us_per_mat = time_method(opt.as_mut(), &mut xs, &gs, eff_steps)?;
                let paper_hours =
                    us_per_mat * PAPER_KERNELS as f64 * PAPER_STEPS as f64 / 1e6 / 3600.0;
                log.record(b, &[
                    ("batch", b as f64),
                    ("us_per_matrix", us_per_mat),
                    ("paper_workload_hours", paper_hours),
                ]);
                bench_rows.push(ScaleRecord {
                    label: label.clone(),
                    batch: b,
                    us_per_matrix: us_per_mat,
                });
                log::info!(
                    "{label} B={b}: {us_per_mat:.2} µs/matrix (paper workload ≈ \
                     {paper_hours:.2} h)"
                );
                // Feasibility must hold even at scale.
                let max_d = xs.iter().map(stiefel::distance).fold(0.0, f64::max);
                assert!(max_d < 0.6, "{label}: drifted at B={b}: {max_d}");
            }
            let wall = log.elapsed();
            let rec = RunRecord { method, label, log, wall_s: wall, spec: Some(spec) };
            common::emit(cfg, &rec, 0)?;
            records.push(rec);
        }
    }

    // Machine-readable sweep summary + the batched-vs-loop speedup map
    // (CI's regression gate).
    let speedups = batched_speedups(&bench_rows, batches);
    for &(b, s) in &speedups {
        log::info!("batched-vs-loop speedup at B={b}: {s:.2}×");
    }
    let json_path = crate::bench::write_scale_json(
        &cfg.out_dir.join("BENCH_scale.json"),
        &bench_rows,
        &speedups,
    )?;
    log::info!("wrote {}", json_path.display());

    common::print_summary(
        "Scalability — µs per 3×3 matrix per step (Fig. 1 mechanism)",
        &records,
        &["us_per_matrix", "paper_workload_hours"],
    );
    Ok(())
}

/// Batched-over-loop throughput ratio per batch size (`>1` ⇒ batched
/// faster).
fn batched_speedups(rows: &[ScaleRecord], batches: &[usize]) -> Vec<(usize, f64)> {
    let find = |label: &str, b: usize| {
        rows.iter().find(|r| r.label == label && r.batch == b).map(|r| r.us_per_matrix)
    };
    batches
        .iter()
        .filter_map(|&b| match (find(LABEL_LOOP, b), find(LABEL_BATCHED, b)) {
            (Some(l), Some(bt)) if bt > 0.0 => Some((b, l / bt)),
            _ => None,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentId;
    use crate::optim::Method;

    #[test]
    fn group_generation_feasible() {
        let mut rng = Rng::seed_from_u64(0);
        let (xs, gs) = make_group(32, &mut rng);
        assert_eq!(xs.len(), 32);
        for x in &xs {
            assert!(stiefel::distance(x) < 1e-5);
        }
        for g in &gs {
            assert!((g.norm() - 0.5).abs() < 1e-5);
        }
    }

    #[test]
    fn rust_pogo_scales_linearly_ish() {
        // Per-matrix time should be roughly flat in B for the host loop.
        let mut rng = Rng::seed_from_u64(1);
        let spec = crate::coordinator::OptimizerSpec::new(Method::Pogo, 0.1);
        let (mut xs1, gs1) = make_group(16, &mut rng);
        let (mut xs2, gs2) = make_group(128, &mut rng);
        let mut o1 = spec.build::<f32>(None, (16, 3, 3)).unwrap();
        let mut o2 = spec.build::<f32>(None, (128, 3, 3)).unwrap();
        let t1 = time_method(o1.as_mut(), &mut xs1, &gs1, 20).unwrap();
        let t2 = time_method(o2.as_mut(), &mut xs2, &gs2, 20).unwrap();
        // Within an order of magnitude per matrix (loop overhead varies).
        assert!(t2 < t1 * 10.0 + 50.0, "t1={t1} t2={t2}");
    }

    #[test]
    fn pogo_contenders_cover_host_engines() {
        let cfg = RunConfig::new(ExperimentId::ScaleMatrices);
        let c = contenders(&cfg, Method::Pogo, false);
        let labels: Vec<&str> = c.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec![LABEL_LOOP, LABEL_BATCHED]);
        assert_eq!(c[0].1.engine, Engine::Rust);
        assert_eq!(c[1].1.engine, Engine::BatchedHost);
        // With a registry the XLA contender joins.
        let c = contenders(&cfg, Method::Pogo, true);
        assert_eq!(c.len(), 3);
        assert_eq!(c[2].1.engine, Engine::Xla);
        // Baselines keep their single engine.
        let c = contenders(&cfg, Method::Rgd, true);
        assert_eq!(c.len(), 1);
        // A --spec replay pins its own engine, no contender fan-out.
        let mut cfg = cfg;
        cfg.spec = Some(OptimizerSpec::new(Method::Pogo, 0.1));
        let c = contenders(&cfg, Method::Pogo, true);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].1.engine, Engine::Rust);
    }

    #[test]
    fn speedup_map_pairs_loop_and_batched() {
        let rows = vec![
            ScaleRecord { label: LABEL_LOOP.into(), batch: 64, us_per_matrix: 4.0 },
            ScaleRecord { label: LABEL_BATCHED.into(), batch: 64, us_per_matrix: 1.0 },
            ScaleRecord { label: LABEL_XLA.into(), batch: 64, us_per_matrix: 0.5 },
            ScaleRecord { label: LABEL_LOOP.into(), batch: 512, us_per_matrix: 4.0 },
        ];
        let s = batched_speedups(&rows, &[64, 512]);
        assert_eq!(s, vec![(64, 4.0)]);
    }
}
