//! The scalability headline (Fig. 1's "3 minutes vs 17 hours"): per-step
//! cost as the number of orthogonal 3×3 matrices grows.
//!
//! Compares, at B ∈ {64, 512, 4096, 32768} matrices:
//! - **POGO[xla]** — ONE batched AOT dispatch per step (the coordinator's
//!   scalability mechanism);
//! - **POGO[rust]** — same math, per-matrix host loop;
//! - **RGD** — per-matrix QR retraction (host, sequential);
//! - **RSDM(r=2)** — per-matrix submanifold QR.
//!
//! Reports µs/matrix/step and the extrapolated wall time for the paper's
//! 218 624-kernel workload at 100 epochs — the Fig. 1 x-axis, regenerated.

use super::common::{self, RunRecord};
use crate::config::{resolve_spec, RunConfig};
use crate::coordinator::MetricLog;
use crate::linalg::MatF;
use crate::manifold::stiefel;
use crate::optim::Orthoptimizer;
use crate::rng::Rng;
use crate::util::Stopwatch;
use anyhow::Result;

pub const BATCHES: [usize; 4] = [64, 512, 4096, 32768];

/// Paper workload: kernels × steps for the extrapolated column.
pub const PAPER_KERNELS: usize = 218_624;
pub const PAPER_STEPS: usize = 9_800; // ≈100 epochs × 98 steps/epoch

fn make_group(b: usize, rng: &mut Rng) -> (Vec<MatF>, Vec<MatF>) {
    let xs: Vec<MatF> = (0..b).map(|_| stiefel::random_point(3, 3, rng)).collect();
    let gs: Vec<MatF> = (0..b)
        .map(|_| {
            let g = MatF::randn(3, 3, rng);
            let n = g.norm();
            g.scale(0.5 / n)
        })
        .collect();
    (xs, gs)
}

/// Time `steps` steps of one optimizer over the group; µs per matrix-step.
fn time_method(
    opt: &mut dyn Orthoptimizer<f32>,
    xs: &mut [MatF],
    gs: &[MatF],
    steps: usize,
) -> Result<f64> {
    let sw = Stopwatch::start();
    for _ in 0..steps {
        opt.step_group(xs, gs)?;
    }
    Ok(sw.seconds() * 1e6 / (steps as f64 * xs.len() as f64))
}

/// Run the scalability sweep.
pub fn run(cfg: &RunConfig) -> Result<()> {
    let reg = common::open_registry()?;
    let steps = if cfg.quick { 3 } else { cfg.steps };
    let mut records = Vec::new();
    let batches: &[usize] = if cfg.quick { &BATCHES[..2] } else { &BATCHES };

    for &method in &cfg.methods {
        let mut log = MetricLog::new(method.name());
        for &b in batches {
            // Retraction baselines get prohibitively slow at large B;
            // subsample their step count to keep the sweep bounded, the
            // per-matrix metric is unaffected.
            let eff_steps = if method.is_matmul_only() { steps } else { steps.min(5) };
            let mut rng = Rng::seed_from_u64(cfg.seed + b as u64);
            let (mut xs, gs) = make_group(b, &mut rng);
            // Engines per the scale preset: POGO is the batched-XLA
            // contender; every baseline runs its host loop (Landing's
            // batched artifacts exist only at the CNN shapes — its
            // per-step math matches POGO's anyway, the loop overhead is
            // the point of this figure).
            let spec = resolve_spec(cfg, method);
            let mut opt = spec.build::<f32>(Some(&reg), (b, 3, 3))?;
            // Warm-up dispatch (compile cache, allocator).
            opt.step_group(&mut xs, &gs)?;
            let us_per_mat = time_method(opt.as_mut(), &mut xs, &gs, eff_steps)?;
            let paper_hours =
                us_per_mat * PAPER_KERNELS as f64 * PAPER_STEPS as f64 / 1e6 / 3600.0;
            log.record(b, &[
                ("batch", b as f64),
                ("us_per_matrix", us_per_mat),
                ("paper_workload_hours", paper_hours),
            ]);
            log::info!(
                "{} B={b}: {us_per_mat:.2} µs/matrix (paper workload ≈ {paper_hours:.2} h)",
                spec.label()
            );
            // Feasibility must hold even at scale.
            let max_d = xs.iter().map(stiefel::distance).fold(0.0, f64::max);
            assert!(max_d < 0.6, "{}: drifted at B={b}: {max_d}", spec.label());
        }
        let wall = log.elapsed();
        let rec = RunRecord {
            method,
            label: method.name().to_string(),
            log,
            wall_s: wall,
            spec: Some(resolve_spec(cfg, method)),
        };
        common::emit(cfg, &rec, 0)?;
        records.push(rec);
    }

    common::print_summary(
        "Scalability — µs per 3×3 matrix per step (Fig. 1 mechanism)",
        &records,
        &["us_per_matrix", "paper_workload_hours"],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Method;

    #[test]
    fn group_generation_feasible() {
        let mut rng = Rng::seed_from_u64(0);
        let (xs, gs) = make_group(32, &mut rng);
        assert_eq!(xs.len(), 32);
        for x in &xs {
            assert!(stiefel::distance(x) < 1e-5);
        }
        for g in &gs {
            assert!((g.norm() - 0.5).abs() < 1e-5);
        }
    }

    #[test]
    fn rust_pogo_scales_linearly_ish() {
        // Per-matrix time should be roughly flat in B for the host loop.
        let mut rng = Rng::seed_from_u64(1);
        let spec = crate::coordinator::OptimizerSpec::new(Method::Pogo, 0.1);
        let (mut xs1, gs1) = make_group(16, &mut rng);
        let (mut xs2, gs2) = make_group(128, &mut rng);
        let mut o1 = spec.build::<f32>(None, (16, 3, 3)).unwrap();
        let mut o2 = spec.build::<f32>(None, (128, 3, 3)).unwrap();
        let t1 = time_method(o1.as_mut(), &mut xs1, &gs1, 20).unwrap();
        let t2 = time_method(o2.as_mut(), &mut xs2, &gs2, 20).unwrap();
        // Within an order of magnitude per matrix (loop overhead varies).
        assert!(t2 < t1 * 10.0 + 50.0, "t1={t1} t2={t2}");
    }
}
