//! Fig. 8: squared unitary circuits — the Born-machine MPS on synthetic
//! binary data, optimized on the COMPLEX Stiefel manifold.
//!
//! The model's 16 isometric complex cores are the reason orthoptimizers
//! exist for this class (§5.3): unitarity makes the squared model
//! self-normalized, so there is no partition function to renormalize.
//! Gradients come from the AOT `born_lossgrad` executable; the unitary
//! optimizer steps run on the Rust complex engine (the cores are tiny —
//! the XLA complex path is exercised by `pogo_step_complex_test`).
//! Protocol per §C.4: plateau-halving lr, early stopping on validation.

use super::common::{self, RunRecord};
use crate::bench::ScaleRecord;
use crate::config::{resolve_spec, RunConfig};
use crate::coordinator::{EarlyStop, LrSchedule, MetricLog, OptimizerSpec, Scheduler};
use crate::data::mnist_like::MnistLike;
use crate::linalg::{CMatF, Complex, Field, Mat};
use crate::manifold::stiefel;
use crate::optim::{Engine, Method, Orthoptimizer};
use crate::rng::Rng;
use crate::runtime::{Arg, Registry};
use crate::util::Stopwatch;
use anyhow::Result;
use std::rc::Rc;

pub const T_SITES: usize = 16;
pub const D_MAX: usize = 8;
pub const TRAIN_BATCH: usize = 64;
pub const EVAL_BATCH: usize = 512;

/// Bond dimensions D_0..D_T (mirrors python/compile/models/born.py).
pub fn bond_dims() -> Vec<usize> {
    (0..=T_SITES)
        .map(|t| {
            let a = 1usize << t.min(30);
            let b = 1usize << (T_SITES - t).min(30);
            a.min(b).min(D_MAX)
        })
        .collect()
}

/// Core shapes (p, n) = (D_t, 2·D_{t−1}).
pub fn core_shapes() -> Vec<(usize, usize)> {
    let d = bond_dims();
    (0..T_SITES).map(|t| (d[t + 1], 2 * d[t])).collect()
}

/// Random isometric cores.
pub fn init_cores(rng: &mut Rng) -> Vec<CMatF> {
    core_shapes()
        .into_iter()
        .map(|(p, n)| stiefel::random_point_complex::<f32>(p, n, rng))
        .collect()
}

/// Max complex-Stiefel distance over the cores.
pub fn max_distance(cores: &[CMatF]) -> f64 {
    cores.iter().map(stiefel::distance_complex).fold(0.0, f64::max)
}

// ---------------------------------------------------------------------------
// Unitary engine race: POGO[loop] vs POGO[batched] on complex groups.
// ---------------------------------------------------------------------------

/// The dominant Born core shape `(D, 2D)` at D = D_MAX — the bulk of the
/// MPS sites (see [`core_shapes`]); the race batches THIS shape.
pub const RACE_SHAPE: (usize, usize) = (D_MAX, 2 * D_MAX);

/// Batch sizes for the unitary race. CI's `bench-smoke` gate reads the
/// B = 1024 speedup from `BENCH_born.json`.
pub const RACE_BATCHES: [usize; 3] = [64, 256, 1024];

/// Engine-qualified labels (stable: `BENCH_born.json` consumers key on
/// them).
pub const LABEL_LOOP: &str = "unitary-POGO[loop]";
pub const LABEL_BATCHED: &str = "unitary-POGO[batched]";

/// B random unitary points of `RACE_SHAPE` plus norm-0.5 complex
/// gradients — the Fig. 8 regime's workload generator, shared with
/// `benches/fig8_born.rs`.
pub fn make_unitary_group(b: usize, rng: &mut Rng) -> (Vec<CMatF>, Vec<CMatF>) {
    let (p, n) = RACE_SHAPE;
    let xs: Vec<CMatF> =
        (0..b).map(|_| stiefel::random_point_complex::<f32>(p, n, rng)).collect();
    let gs: Vec<CMatF> = (0..b)
        .map(|_| {
            let g = CMatF::randn(p, n, rng);
            let nn = g.norm();
            g.scale(Complex::from_f64(0.5 / nn as f64))
        })
        .collect();
    (xs, gs)
}

fn time_unitary(
    opt: &mut dyn Orthoptimizer<Complex<f32>>,
    xs: &mut [CMatF],
    gs: &[CMatF],
    steps: usize,
) -> Result<f64> {
    let sw = Stopwatch::start();
    for _ in 0..steps {
        opt.step_group(xs, gs)?;
    }
    Ok(sw.seconds() * 1e6 / (steps as f64 * xs.len() as f64))
}

/// Race the per-matrix unitary loop against the batched complex engine
/// at the Fig. 8 shape. Returns (`BENCH_born.json` rows, speedup map).
/// Host-only — runs anywhere, no artifacts needed.
pub fn race_unitary_engines(
    quick: bool,
    seed: u64,
) -> Result<(Vec<ScaleRecord>, Vec<(usize, f64)>)> {
    let steps = if quick { 3 } else { 10 };
    let batches: &[usize] = if quick { &RACE_BATCHES[..2] } else { &RACE_BATCHES };
    let preset = OptimizerSpec::new(Method::Pogo, 0.1);
    let mut rows: Vec<ScaleRecord> = Vec::new();
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for &b in batches {
        let mut per_engine = Vec::new();
        for (label, engine) in
            [(LABEL_LOOP, Engine::Rust), (LABEL_BATCHED, Engine::BatchedHost)]
        {
            let mut rng = Rng::seed_from_u64(seed + b as u64);
            let (mut xs, gs) = make_unitary_group(b, &mut rng);
            let mut opt = preset.with_engine(engine).build_unitary::<f32>(b)?;
            opt.step_group(&mut xs, &gs)?; // warm-up (pool, allocator)
            let us = time_unitary(opt.as_mut(), &mut xs, &gs, steps)?;
            // Feasibility must hold even at scale.
            let max_d = max_distance(&xs);
            anyhow::ensure!(max_d < 1e-3, "{label}: drifted at B={b}: {max_d}");
            log::info!("{label} B={b}: {us:.2} µs/matrix");
            rows.push(ScaleRecord { label: label.to_string(), batch: b, us_per_matrix: us });
            per_engine.push(us);
        }
        if per_engine[1] > 0.0 {
            speedups.push((b, per_engine[0] / per_engine[1]));
        }
    }
    Ok((rows, speedups))
}

struct BornGrads {
    lossgrad: Rc<crate::runtime::Executable>,
    eval: Rc<crate::runtime::Executable>,
    data: MnistLike,
    eval_bits: Vec<i32>,
}

impl BornGrads {
    fn new(reg: &Registry, seed: u64) -> Result<BornGrads> {
        let mut data = MnistLike::new(seed, T_SITES, 8, 0.05);
        let eval_bits = data.batch(EVAL_BATCH);
        Ok(BornGrads {
            lossgrad: reg.get("born_lossgrad")?,
            eval: reg.get("born_eval")?,
            data,
            eval_bits,
        })
    }

    fn core_args<'a>(cores: &'a [CMatF], bufs: &'a mut Vec<(Vec<f32>, Vec<usize>)>) {
        for c in cores {
            let (p, n) = c.shape();
            // Complex parameters cross the PJRT boundary as split re/im
            // planes (two f32 literals per core).
            bufs.push((c.re_vec(), vec![p, n]));
            bufs.push((c.im_vec(), vec![p, n]));
        }
    }

    /// Loss (mean NLL nats) + per-core complex gradients.
    fn eval_step(&mut self, cores: &[CMatF]) -> Result<(f64, Vec<CMatF>)> {
        let bits = self.data.batch(TRAIN_BATCH);
        let mut bufs = Vec::new();
        Self::core_args(cores, &mut bufs);
        let mut args: Vec<Arg> = bufs.iter().map(|(b, s)| Arg::F32(b, s.clone())).collect();
        args.push(Arg::I32(&bits, vec![TRAIN_BATCH, T_SITES]));
        let outs = self.lossgrad.run(&args)?;
        let loss = crate::runtime::literal_to_scalar(&outs[0])? as f64;
        let mut grads = Vec::with_capacity(cores.len());
        for (i, c) in cores.iter().enumerate() {
            let (p, n) = c.shape();
            let re = crate::runtime::literal_to_vec(&outs[1 + 2 * i])?;
            let im = crate::runtime::literal_to_vec(&outs[2 + 2 * i])?;
            grads.push(CMatF::from_parts(Mat::from_vec(p, n, re), Mat::from_vec(p, n, im)));
        }
        Ok((loss, grads))
    }

    /// Validation bits-per-dim.
    fn eval_bpd(&self, cores: &[CMatF]) -> Result<f64> {
        let mut bufs = Vec::new();
        Self::core_args(cores, &mut bufs);
        let mut args: Vec<Arg> = bufs.iter().map(|(b, s)| Arg::F32(b, s.clone())).collect();
        args.push(Arg::I32(&self.eval_bits, vec![EVAL_BATCH, T_SITES]));
        let outs = self.eval.run(&args)?;
        Ok(crate::runtime::literal_to_scalar(&outs[0])? as f64)
    }
}

/// Run the Fig. 8 experiment. Unitary optimizers come from
/// `OptimizerSpec::build_unitary` — the same single construction path as
/// the real-Stiefel drivers (methods without a complex engine error out
/// instead of silently falling back).
pub fn run(cfg: &RunConfig) -> Result<()> {
    // Host-only engine race first (loop vs batched unitary POGO): runs
    // anywhere, and its BENCH_born.json is what CI's bench-smoke gates
    // on — the complex twin of scale.rs's BENCH_scale.json.
    let (rows, speedups) = race_unitary_engines(cfg.quick, cfg.seed)?;
    for &(b, s) in &speedups {
        log::info!("unitary batched-vs-loop speedup at B={b}: {s:.2}×");
    }
    let json_path =
        crate::bench::write_born_json(&cfg.out_dir.join("BENCH_born.json"), &rows, &speedups)?;
    log::info!("wrote {}", json_path.display());

    // The training experiment itself needs the AOT loss/grad artifacts.
    let reg = match common::open_registry() {
        Ok(r) => r,
        Err(e) => {
            log::warn!("no artifact registry — ran the engine race only ({e:#})");
            return Ok(());
        }
    };
    let steps = if cfg.quick { 10 } else { cfg.steps };
    let eval_every = (steps / 20).max(1);
    let mut records = Vec::new();

    for rep in 0..cfg.repetitions {
        for &method in &cfg.methods {
            let mut rng = Rng::seed_from_u64(cfg.seed + 31 * rep as u64);
            let mut cores = init_cores(&mut rng);
            let mut grads = BornGrads::new(&reg, cfg.seed + rep as u64)?;
            let spec = resolve_spec(cfg, method);
            let mut opt = spec.build_unitary::<f32>(cores.len())?;
            let mut log = MetricLog::new(method.name());
            // §C.4 protocol: halve on a 10-observation plateau, early stop.
            let mut sched = Scheduler::new(
                LrSchedule::Plateau { patience: 10, factor: 0.5, min_delta: 1e-4 },
                opt.lr(),
            );
            let mut early = EarlyStop::new(25, 1e-5);

            for s in 0..steps {
                let (loss, gs) = grads.eval_step(&cores)?;
                for (i, (c, g)) in cores.iter_mut().zip(&gs).enumerate() {
                    opt.step(i, c, g)?;
                }
                if s % eval_every == 0 || s + 1 == steps {
                    let bpd = grads.eval_bpd(&cores)?;
                    let d = max_distance(&cores);
                    log.record(s, &[
                        ("loss", loss),
                        ("bpd", bpd),
                        ("distance", d),
                        ("lr", opt.lr()),
                    ]);
                    log::info!(
                        "{} step {s}: bpd {bpd:.4} dist {d:.2e} lr {:.1e}",
                        method.name(),
                        opt.lr()
                    );
                    opt.set_lr(sched.observe(bpd));
                    if early.observe(bpd) {
                        log::info!("{}: early stop at {s}", method.name());
                        break;
                    }
                }
            }
            let wall = log.elapsed();
            let rec = RunRecord {
                method,
                label: method.name().to_string(),
                log,
                wall_s: wall,
                spec: Some(spec),
            };
            common::emit(cfg, &rec, rep)?;
            records.push(rec);
        }
        // Reference line: the generator's entropy bound.
        let ds = MnistLike::new(cfg.seed + rep as u64, T_SITES, 8, 0.05);
        log::info!("data entropy bound ≈ {:.3} bpd", ds.entropy_bound_bpd());
    }

    common::print_summary(
        "Fig. 8 — squared unitary circuit (Born MPS, complex Stiefel)",
        &records,
        &["best/bpd", "distance"],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bond_dims_match_isometry_requirement() {
        let shapes = core_shapes();
        assert_eq!(shapes.len(), T_SITES);
        for &(p, n) in &shapes {
            assert!(p <= n, "core ({p},{n}) not wide");
        }
        // Boundary dims collapse to 1.
        assert_eq!(bond_dims()[0], 1);
        assert_eq!(bond_dims()[T_SITES], 1);
    }

    #[test]
    fn init_cores_are_isometric() {
        let mut rng = Rng::seed_from_u64(0);
        let cores = init_cores(&mut rng);
        assert_eq!(cores.len(), T_SITES);
        assert!(max_distance(&cores) < 1e-5);
    }

    #[test]
    fn unitary_optimizers_build_for_lineup() {
        use crate::config::{spec_for, ExperimentId};
        use crate::optim::Method;
        for m in [Method::Pogo, Method::Landing, Method::LandingPC, Method::Slpg,
                  Method::Rgd] {
            let opt =
                spec_for(ExperimentId::Fig8Born, m).build_unitary::<f32>(16).unwrap();
            assert!(opt.lr() > 0.0);
        }
        // No silent fallback: methods without a complex engine refuse.
        assert!(spec_for(ExperimentId::Fig8Born, Method::Rsdm)
            .build_unitary::<f32>(16)
            .is_err());
    }
}
