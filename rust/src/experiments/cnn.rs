//! Figs. 1/6/7: CNN classification with orthogonal filters or kernels.
//!
//! The compute graph (forward/backward) is one AOT executable
//! (`cnn_{filters,kernels}_lossgrad`); the coordinator routes filter/kernel
//! gradients to the per-group orthoptimizer and the classifier head to
//! Adam. Test accuracy comes from the `_eval` executable on a held-out
//! synthetic-CIFAR batch.
//!
//! Shapes (see `python/compile/models/cnn.py`):
//! - filters: 3 wide matrices (24, 27), (64, 216), (128, 576);
//! - kernels: 72 + 1536 + 8192 = 9800 orthogonal 3×3 matrices, dispatched
//!   as three batched groups — the paper's many-matrix regime.

use super::common::{self, RunRecord};
use crate::config::{resolve_spec, RunConfig};
use crate::coordinator::{OptimizerSpec, ParamStore, Trainer, TrainerConfig};
use crate::data::cifar_like::CifarLike;
use crate::linalg::MatF;
use crate::optim::Method;
use crate::rng::Rng;
use crate::runtime::{Arg, Registry};
use anyhow::Result;
use std::rc::Rc;

/// Which parameterization (two separate experiments in §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parameterization {
    Filters,
    Kernels,
}

/// Mirrors python/compile/models/cnn.py — keep in sync.
pub const FILTER_SHAPES: [(usize, usize); 3] = [(24, 27), (64, 216), (128, 576)];
pub const KERNEL_COUNTS: [usize; 3] = [72, 1536, 8192];
pub const HEAD_SHAPE: (usize, usize) = (128, 10);
pub const TRAIN_BATCH: usize = 64;
pub const EVAL_BATCH: usize = 256;

/// Build the parameter store for one run. For the unconstrained Adam
/// baseline everything is registered free.
fn build_store(
    param: Parameterization,
    constrained: bool,
    rng: &mut Rng,
) -> ParamStore {
    let mut store = ParamStore::new();
    match param {
        Parameterization::Filters => {
            for (li, &(o, ik)) in FILTER_SHAPES.iter().enumerate() {
                let x = crate::manifold::stiefel::random_point(o, ik, rng);
                if constrained {
                    store.add_stiefel_keyed(format!("w{li}"), x, format!("w{li}"));
                } else {
                    store.add_free(format!("w{li}"), x);
                }
            }
        }
        Parameterization::Kernels => {
            for (li, &count) in KERNEL_COUNTS.iter().enumerate() {
                for i in 0..count {
                    let x = crate::manifold::stiefel::random_point(3, 3, rng);
                    if constrained {
                        store.add_stiefel_keyed(format!("k{li}_{i}"), x, format!("k{li}"));
                    } else {
                        store.add_free(format!("k{li}_{i}"), x);
                    }
                }
            }
        }
    }
    store.add_free("head", MatF::randn(HEAD_SHAPE.0, HEAD_SHAPE.1, rng).scale(0.1));
    store
}

/// Pack the store into executable args + run loss/grad, mapping gradients
/// back to per-parameter `MatF`s.
struct CnnGrads {
    lossgrad: Rc<crate::runtime::Executable>,
    eval: Rc<crate::runtime::Executable>,
    param: Parameterization,
    data: CifarLike,
    eval_images: Vec<f32>,
    eval_labels: Vec<i32>,
}

impl CnnGrads {
    fn new(reg: &Registry, param: Parameterization, seed: u64) -> Result<CnnGrads> {
        let (lg, ev) = match param {
            Parameterization::Filters => ("cnn_filters_lossgrad", "cnn_filters_eval"),
            Parameterization::Kernels => ("cnn_kernels_lossgrad", "cnn_kernels_eval"),
        };
        let mut data = CifarLike::new(seed, 0.15);
        let (eval_images, eval_labels) = data.batch(EVAL_BATCH);
        Ok(CnnGrads {
            lossgrad: reg.get(lg)?,
            eval: reg.get(ev)?,
            param,
            data,
            eval_images,
            eval_labels,
        })
    }

    /// Layer slices of the store: (per-layer param index ranges, head idx).
    fn layout(&self, store: &ParamStore) -> (Vec<std::ops::Range<usize>>, usize) {
        match self.param {
            Parameterization::Filters => (vec![0..1, 1..2, 2..3], 3),
            Parameterization::Kernels => {
                let mut ranges = Vec::new();
                let mut at = 0;
                for &c in &KERNEL_COUNTS {
                    ranges.push(at..at + c);
                    at += c;
                }
                debug_assert_eq!(store.len(), at + 1);
                (ranges, at)
            }
        }
    }

    fn eval_step(&mut self, store: &ParamStore) -> Result<(f64, Vec<MatF>)> {
        let (ranges, head_idx) = self.layout(store);
        let (images, labels) = self.data.batch(TRAIN_BATCH);

        // Assemble args: 3 layer params (+ head), images, labels.
        let layer_bufs: Vec<(Vec<f32>, Vec<usize>)> = ranges
            .iter()
            .map(|r| {
                let mats: Vec<MatF> =
                    r.clone().map(|i| store.mat(i).clone()).collect();
                let (p, n) = mats[0].shape();
                let packed = crate::runtime::pack_batch(&mats).unwrap();
                match self.param {
                    Parameterization::Filters => (packed, vec![p, n]),
                    Parameterization::Kernels => (packed, vec![mats.len(), p, n]),
                }
            })
            .collect();
        let head = store.mat(head_idx);
        let mut args: Vec<Arg> = Vec::new();
        for (buf, shape) in &layer_bufs {
            args.push(Arg::F32(buf, shape.clone()));
        }
        args.push(Arg::Mat(head));
        args.push(Arg::F32(&images, vec![TRAIN_BATCH, 32, 32, 3]));
        args.push(Arg::I32(&labels, vec![TRAIN_BATCH]));

        let outs = self.lossgrad.run(&args)?;
        let loss = crate::runtime::literal_to_scalar(&outs[0])? as f64;

        // Map gradient outputs back to store order.
        let mut grads: Vec<MatF> = vec![MatF::zeros(1, 1); store.len()];
        for (li, r) in ranges.iter().enumerate() {
            let flat = crate::runtime::literal_to_vec(&outs[1 + li])?;
            let (p, n) = store.mat(r.start).shape();
            let per = p * n;
            for (j, i) in r.clone().enumerate() {
                grads[i] = MatF::from_vec(p, n, flat[j * per..(j + 1) * per].to_vec());
            }
        }
        let head_grad = crate::runtime::literal_to_vec(&outs[1 + ranges.len()])?;
        grads[head_idx] = MatF::from_vec(HEAD_SHAPE.0, HEAD_SHAPE.1, head_grad);
        Ok((loss, grads))
    }

    /// Held-out loss + accuracy.
    fn test_metrics(&self, store: &ParamStore) -> Result<(f64, f64)> {
        let (ranges, head_idx) = self.layout(store);
        let layer_bufs: Vec<(Vec<f32>, Vec<usize>)> = ranges
            .iter()
            .map(|r| {
                let mats: Vec<MatF> =
                    r.clone().map(|i| store.mat(i).clone()).collect();
                let (p, n) = mats[0].shape();
                let packed = crate::runtime::pack_batch(&mats).unwrap();
                match self.param {
                    Parameterization::Filters => (packed, vec![p, n]),
                    Parameterization::Kernels => (packed, vec![mats.len(), p, n]),
                }
            })
            .collect();
        let mut args: Vec<Arg> = Vec::new();
        for (buf, shape) in &layer_bufs {
            args.push(Arg::F32(buf, shape.clone()));
        }
        args.push(Arg::Mat(store.mat(head_idx)));
        args.push(Arg::F32(&self.eval_images, vec![EVAL_BATCH, 32, 32, 3]));
        args.push(Arg::I32(&self.eval_labels, vec![EVAL_BATCH]));
        let outs = self.eval.run(&args)?;
        let loss = crate::runtime::literal_to_scalar(&outs[0])? as f64;
        let acc = crate::runtime::literal_to_scalar(&outs[1])? as f64;
        Ok((loss, acc))
    }
}

/// Run the CNN experiment for one parameterization.
pub fn run(cfg: &RunConfig, param: Parameterization) -> Result<()> {
    let reg = common::open_registry()?;
    let steps = if cfg.quick { 6 } else { cfg.steps };
    let eval_every = (steps / 10).max(1);
    let mut records = Vec::new();

    for rep in 0..cfg.repetitions {
        for &method in &cfg.methods {
            let mut rng = Rng::seed_from_u64(cfg.seed + 7 * rep as u64);
            let constrained = method != Method::Adam;
            let store = build_store(param, constrained, &mut rng);
            let spec: OptimizerSpec =
                common::with_engine_for(cfg, resolve_spec(cfg, method));
            let mut grads = CnnGrads::new(&reg, param, cfg.seed + rep as u64)?;
            let mut tr = Trainer::new(
                store,
                spec,
                Some(&reg),
                TrainerConfig {
                    max_steps: steps,
                    log_every: eval_every,
                    free_lr: 0.01,
                    ..Default::default()
                },
            )?;

            for s in 0..steps {
                let loss = {
                    let g = &mut grads;
                    let mut src = |store: &ParamStore| g.eval_step(store);
                    tr.step(&mut src)?
                };
                if s % eval_every == 0 || s + 1 == steps {
                    let (test_loss, acc) = grads.test_metrics(&tr.store)?;
                    let d = tr.store.max_stiefel_distance();
                    let nd = tr.store.max_normalized_distance();
                    tr.log.record(tr.step_idx(), &[
                        ("loss", loss),
                        ("test_loss", test_loss),
                        ("test_acc", acc),
                        ("distance", d),
                        ("norm_distance", nd),
                    ]);
                    log::info!(
                        "{} step {s}: loss {loss:.3} acc {acc:.3} dist {d:.2e}",
                        spec.label()
                    );
                }
            }
            let wall = tr.log.elapsed();
            let rec = RunRecord {
                method,
                label: spec.label(),
                log: tr.log,
                wall_s: wall,
                spec: Some(spec),
            };
            common::emit(cfg, &rec, rep)?;
            records.push(rec);
        }
    }

    let title = match param {
        Parameterization::Filters => "Fig. 1/6 — CNN, orthogonal filters",
        Parameterization::Kernels => "Fig. 1/6/7 — CNN, orthogonal kernels (9800 mats)",
    };
    common::print_summary(title, &records, &["max/test_acc", "norm_distance"]);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_layouts_match_artifact_signatures() {
        let mut rng = Rng::seed_from_u64(0);
        let s = build_store(Parameterization::Filters, true, &mut rng);
        assert_eq!(s.len(), 4); // 3 filters + head
        assert_eq!(s.stiefel_groups().len(), 3);

        let s = build_store(Parameterization::Kernels, true, &mut rng);
        assert_eq!(s.len(), KERNEL_COUNTS.iter().sum::<usize>() + 1);
        let groups = s.stiefel_groups();
        assert_eq!(groups.len(), 3);
        let sizes: Vec<usize> = groups.iter().map(|g| g.indices.len()).collect();
        assert_eq!(sizes, KERNEL_COUNTS.to_vec());
    }

    #[test]
    fn adam_baseline_has_no_constraints() {
        let mut rng = Rng::seed_from_u64(1);
        let s = build_store(Parameterization::Filters, false, &mut rng);
        assert!(s.stiefel_groups().is_empty());
        assert_eq!(s.free_indices().len(), 4);
    }
}
