//! Fig. 4 (right): the orthogonal Procrustes problem.
//!
//! `min ‖A X − B‖² s.t. X ∈ St(p, n)` (Eq. 15), p = n, A and B standard
//! Gaussian. The analytic optimum is the polar factor of `Aᵀ B` (Gower &
//! Dijksterhuis 2004), computed on the Newton–Schulz substrate, giving the
//! exact optimality-gap reference.

use super::common::{self, RunRecord};
use crate::config::{resolve_spec, RunConfig};
use crate::coordinator::{ParamStore, Trainer, TrainerConfig};
use crate::linalg::{matmul, matmul_at_b, polar_project, MatF, PolarOpts};
use crate::manifold::stiefel;
use crate::rng::Rng;
use crate::runtime::{Arg, Registry};
use anyhow::Result;

/// Problem instance.
pub struct ProcrustesProblem {
    pub a: MatF,
    pub b: MatF,
    pub n: usize,
    pub optimal_loss: f64,
}

pub fn build_problem(n: usize, rng: &mut Rng) -> ProcrustesProblem {
    let a = MatF::randn(n, n, rng);
    let b = MatF::randn(n, n, rng);
    // X* = polar(Aᵀ B); compute in f64 for accuracy.
    let atb = matmul_at_b(&a, &b).cast::<f64>();
    let xstar = polar_project(&atb, PolarOpts { tol: 1e-10, max_iters: 200 });
    let xstar_f: MatF = xstar.cast();
    let r = matmul(&a, &xstar_f).sub(&b);
    let optimal_loss = r.norm_sq() as f64;
    ProcrustesProblem { a, b, n, optimal_loss }
}

pub fn gap(problem: &ProcrustesProblem, loss: f64) -> f64 {
    (loss - problem.optimal_loss) / problem.optimal_loss.abs()
}

/// Rust closed-form gradient: ∇ = 2 Aᵀ(A X − B).
pub fn lossgrad_rust(x: &MatF, prob: &ProcrustesProblem) -> (f64, MatF) {
    let r = matmul(&prob.a, x).sub(&prob.b);
    let loss = r.norm_sq() as f64;
    (loss, matmul_at_b(&prob.a, &r).scale(2.0))
}

/// AOT gradient source.
pub struct ProcGrads<'r> {
    exe: std::rc::Rc<crate::runtime::Executable>,
    problem: &'r ProcrustesProblem,
}

impl<'r> ProcGrads<'r> {
    pub fn new(reg: &Registry, problem: &'r ProcrustesProblem) -> Result<Self> {
        let name = format!("procrustes_lossgrad_{}x{}", problem.n, problem.n);
        Ok(ProcGrads { exe: reg.get(&name)?, problem })
    }

    pub fn eval_one(&self, x: &MatF) -> Result<(f64, MatF)> {
        let outs =
            self.exe.run(&[Arg::Mat(x), Arg::Mat(&self.problem.a), Arg::Mat(&self.problem.b)])?;
        let loss = crate::runtime::literal_to_scalar(&outs[0])? as f64;
        let grad = crate::runtime::literal_to_mat(&outs[1], self.problem.n, self.problem.n)?;
        Ok((loss, grad))
    }
}

/// Run the Fig. 4 Procrustes comparison.
pub fn run(cfg: &RunConfig) -> Result<()> {
    let reg = common::open_registry()?;
    let n = if cfg.full { 2000 } else { 400 };
    let n = if cfg.quick { 40 } else { n };
    let mut records = Vec::new();

    for rep in 0..cfg.repetitions {
        let mut rng = Rng::seed_from_u64(cfg.seed + 1000 + rep as u64);
        let problem = build_problem(n, &mut rng);
        let x0 = stiefel::random_point(n, n, &mut rng);

        for &method in &cfg.methods {
            let spec = common::with_engine_for(cfg, resolve_spec(cfg, method));
            let mut store = ParamStore::new();
            store.add_stiefel("x", x0.clone());
            let mut tr = Trainer::new(
                store,
                spec,
                Some(&reg),
                TrainerConfig { max_steps: cfg.steps, log_every: 1, ..Default::default() },
            )?;
            let grads =
                if cfg.quick { None } else { Some(ProcGrads::new(&reg, &problem)?) };
            // §Perf: XLA distance probe (see pca.rs).
            let dist_exe =
                if cfg.quick { None } else { Some(reg.get(&format!("distance_b1_{n}x{n}"))?) };

            let mut last_gap = f64::INFINITY;
            for _ in 0..cfg.steps {
                let loss = match &grads {
                    Some(g) => {
                        let gref = g;
                        let mut src = |store: &ParamStore| {
                            let (l, gr) = gref.eval_one(store.mat(0))?;
                            Ok((l, vec![gr]))
                        };
                        tr.step(&mut src)?
                    }
                    None => {
                        let pref = &problem;
                        let mut src = move |store: &ParamStore| {
                            let (l, gr) = lossgrad_rust(store.mat(0), pref);
                            Ok((l, vec![gr]))
                        };
                        tr.step(&mut src)?
                    }
                };
                last_gap = gap(&problem, loss);
                let d = match &dist_exe {
                    Some(exe) => {
                        let xs = [tr.store.mat(0).clone()];
                        let outs = exe.run(&[Arg::Batch(&xs)])?;
                        crate::runtime::literal_to_scalar(&outs[0])? as f64
                    }
                    None => stiefel::distance(tr.store.mat(0)),
                };
                tr.log.record(tr.step_idx(), &[
                    ("loss", loss),
                    ("gap", last_gap.max(1e-12)),
                    ("distance", d),
                ]);
                if last_gap <= 1e-6 {
                    break;
                }
            }
            let wall = tr.log.elapsed();
            log::info!(
                "{}: gap {:.2e} in {} ({} steps)",
                spec.label(),
                last_gap,
                crate::util::fmt_duration(wall),
                tr.step_idx()
            );
            let rec = RunRecord {
                method,
                label: spec.label(),
                log: tr.log,
                wall_s: wall,
                spec: Some(spec),
            };
            common::emit(cfg, &rec, rep)?;
            records.push(rec);
        }
    }

    common::print_summary(
        &format!("Fig. 4 — orthogonal Procrustes (n={n})"),
        &records,
        &["best/gap", "distance"],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimum_beats_random_points() {
        let mut rng = Rng::seed_from_u64(0);
        let prob = build_problem(12, &mut rng);
        for _ in 0..5 {
            let x = stiefel::random_point(12, 12, &mut rng);
            let (l, _) = lossgrad_rust(&x, &prob);
            assert!(l >= prob.optimal_loss - 1e-2, "{l} < {}", prob.optimal_loss);
        }
    }

    #[test]
    fn gap_zero_at_optimum() {
        let mut rng = Rng::seed_from_u64(1);
        let prob = build_problem(10, &mut rng);
        let atb = matmul_at_b(&prob.a, &prob.b).cast::<f64>();
        let xstar: MatF =
            polar_project(&atb, PolarOpts { tol: 1e-10, max_iters: 200 }).cast();
        let (l, _) = lossgrad_rust(&xstar, &prob);
        assert!(gap(&prob, l).abs() < 1e-3);
    }

    #[test]
    fn pogo_closes_gap_small_instance() {
        use crate::optim::Orthoptimizer;
        let mut rng = Rng::seed_from_u64(2);
        let prob = build_problem(16, &mut rng);
        let mut x = stiefel::random_point(16, 16, &mut rng);
        let mut opt = crate::optim::pogo::Pogo::<f32>::new(
            crate::optim::pogo::PogoConfig { lr: 0.002, ..Default::default() },
            1,
        );
        let (l0, _) = lossgrad_rust(&x, &prob);
        let mut l = l0;
        for _ in 0..500 {
            let (li, g) = lossgrad_rust(&x, &prob);
            opt.step(0, &mut x, &g).unwrap();
            l = li;
        }
        assert!(
            l - prob.optimal_loss < 0.3 * (l0 - prob.optimal_loss),
            "gap not closed: {l0} → {l} (opt {})",
            prob.optimal_loss
        );
    }
}
