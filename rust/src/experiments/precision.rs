//! Fig. C.1: the tensor-precision ablation on online PCA.
//!
//! Three arithmetic modes over the same trajectory seeds:
//! - `f32` — the default experiment dtype;
//! - `f64` — "all 64-bit": slower, and RSDM's manifold drift disappears
//!   (the paper's §C.5 finding);
//! - `bf16` — matmul inputs truncated to bfloat16 mantissas (emulating
//!   reduced-mantissa tensor units): faster units in exchange for several
//!   orders of magnitude more feasibility error.
//!
//! All runs use the pure-Rust engines so the precision is actually what we
//! claim end-to-end (XLA CPU would keep f32 accumulators).

use super::common::{self, RunRecord};
use super::pca::{self, PcaProblem};
use crate::config::{resolve_spec, RunConfig};
use crate::coordinator::{MetricLog, OptimizerSpec};
use crate::linalg::{Mat, Scalar};
use crate::manifold::stiefel;
use crate::optim::Engine;
use crate::rng::Rng;
use anyhow::Result;

/// Arithmetic mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F32,
    F64,
    Bf16,
}

impl Precision {
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
            Precision::Bf16 => "bf16",
        }
    }
}

/// One (spec, precision) run on a shared problem instance. The optimizer
/// is built by the generic `OptimizerSpec::build::<S>` — the same single
/// construction path every other driver uses, now at arbitrary precision.
fn run_one<S: Scalar>(
    spec: &OptimizerSpec,
    problem: &PcaProblem,
    x0: &Mat<S>,
    steps: usize,
    truncate_bf16: bool,
) -> Result<MetricLog> {
    let aat: Mat<S> = problem.aat.cast();
    let mut x = x0.clone();
    let mut opt = spec.build::<S>(None, (1, x0.rows(), x0.cols()))?;
    let label = format!("{}/{}", spec.method.name(), if truncate_bf16 { "bf16" }
                        else if S::EPS.to_f64() < 1e-10 { "f64" } else { "f32" });
    let mut log = MetricLog::new(label);
    for s in 0..steps {
        let (x_in, aat_in) = if truncate_bf16 {
            (x.truncate_bf16(), aat.truncate_bf16())
        } else {
            (x.clone(), aat.clone())
        };
        let (loss, grad) = pca::lossgrad_rust(&x_in, &aat_in);
        opt.step(0, &mut x, &grad)?;
        if truncate_bf16 {
            x = x.truncate_bf16();
        }
        if s % 5 == 0 || s + 1 == steps {
            let d = stiefel::distance_t(&x);
            let gap = pca::gap(problem, loss);
            log.record(s, &[("gap", gap.max(1e-12)), ("distance", d.max(1e-12)),
                            ("loss", loss)]);
        }
    }
    Ok(log)
}

/// Run the precision ablation.
pub fn run(cfg: &RunConfig) -> Result<()> {
    let (p, n) = if cfg.quick { (30, 40) } else { (150, 200) };
    let mut records = Vec::new();
    let steps = if cfg.quick { 40 } else { cfg.steps };

    for rep in 0..cfg.repetitions {
        let mut rng = Rng::seed_from_u64(cfg.seed + rep as u64);
        let problem = pca::build_problem(p, n, &mut rng);
        let x0_d = stiefel::random_point_t::<f64>(p, n, &mut rng);
        let x0_f: Mat<f32> = x0_d.cast();

        for &method in &cfg.methods {
            // Precision is the variable under test, so the engine is
            // pinned to Rust regardless of the preset/override.
            let spec = resolve_spec(cfg, method).with_engine(Engine::Rust);
            for &prec in &[Precision::F32, Precision::F64, Precision::Bf16] {
                let log = match prec {
                    Precision::F32 => {
                        run_one::<f32>(&spec, &problem, &x0_f, steps, false)?
                    }
                    Precision::F64 => {
                        run_one::<f64>(&spec, &problem, &x0_d, steps, false)?
                    }
                    Precision::Bf16 => {
                        run_one::<f32>(&spec, &problem, &x0_f, steps, true)?
                    }
                };
                let wall = log.elapsed();
                log::info!(
                    "{}: final dist {:.2e} gap {:.2e} in {}",
                    log.label,
                    log.last("distance").unwrap_or(f64::NAN),
                    log.last("gap").unwrap_or(f64::NAN),
                    crate::util::fmt_duration(wall)
                );
                let rec = RunRecord {
                    method,
                    label: log.label.clone(),
                    log,
                    wall_s: wall,
                    spec: Some(spec),
                };
                common::emit(cfg, &rec, rep)?;
                records.push(rec);
            }
        }
    }

    common::print_summary(
        &format!("Fig. C.1 — precision ablation on PCA (p={p}, n={n})"),
        &records,
        &["best/gap", "distance"],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::config::{spec_for, ExperimentId};
    use crate::optim::Method;

    #[test]
    fn rsdm_precision_ordering() {
        // THE §C.5 claim: RSDM's drift is numerical — f64 ≪ f32 ≤ bf16.
        let mut rng = Rng::seed_from_u64(0);
        let problem = pca::build_problem(20, 30, &mut rng);
        let x0_d = stiefel::random_point_t::<f64>(20, 30, &mut rng);
        let x0_f: Mat<f32> = x0_d.cast();
        let spec = spec_for(ExperimentId::FigC1Precision, Method::Rsdm);
        let steps = 300;
        let d32 = run_one::<f32>(&spec, &problem, &x0_f, steps, false)
            .unwrap()
            .last("distance")
            .unwrap();
        let d64 = run_one::<f64>(&spec, &problem, &x0_d, steps, false)
            .unwrap()
            .last("distance")
            .unwrap();
        let dbf = run_one::<f32>(&spec, &problem, &x0_f, steps, true)
            .unwrap()
            .last("distance")
            .unwrap();
        assert!(d64 < d32, "f64 {d64} should beat f32 {d32}");
        assert!(d32 < dbf, "f32 {d32} should beat bf16 {dbf}");
        assert!(d64 < 1e-6, "f64 drift {d64}");
    }

    #[test]
    fn pogo_robust_across_precisions() {
        // POGO's normal step re-attracts every iteration, so even bf16
        // stays within a modest band (the paper's "benefits from mantissa
        // reduction" point).
        let mut rng = Rng::seed_from_u64(1);
        let problem = pca::build_problem(16, 24, &mut rng);
        let x0_d = stiefel::random_point_t::<f64>(16, 24, &mut rng);
        let x0_f: Mat<f32> = x0_d.cast();
        let spec = spec_for(ExperimentId::FigC1Precision, Method::Pogo);
        let dbf = run_one::<f32>(&spec, &problem, &x0_f, 200, true)
            .unwrap()
            .last("distance")
            .unwrap();
        assert!(dbf < 0.1, "POGO bf16 drift {dbf}");
    }
}
