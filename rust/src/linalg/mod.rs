//! Dense linear-algebra substrate.
//!
//! The offline registry has no BLAS/LAPACK binding and no `ndarray`, so the
//! whole reproduction stands on this module: a row-major dense matrix type
//! generic over a [`Field`] element (`f32`/`f64` for the real Stiefel
//! manifold, [`Complex<S>`] for the unitary one), cache-blocked threaded
//! matrix multiplication, Householder QR, a Jacobi symmetric eigensolver,
//! and Newton–Schulz polar decomposition.
//!
//! Design notes:
//! - Row-major storage everywhere (matches the HLO/XLA literal layout used
//!   by the runtime, so buffers cross the PJRT boundary without copies;
//!   complex matrices ship as split re/im planes — see `complexmat`).
//! - The paper's matrices are *wide row-orthogonal* `X ∈ F^{p×n}`, `p ≤ n`,
//!   with `X Xᴴ = I_p`; helper names follow that convention (`gram(X)` is
//!   the small `p×p` product `X Xᴴ`).
//! - One element abstraction, two manifolds (paper §2, fn. 1): the matmul
//!   kernels take `Aᴴ` adjoints (`matmul_ah_b` / `matmul_a_bh`), which on
//!   real fields degenerate to the familiar transposed products — the
//!   real-named aliases `matmul_at_b` / `matmul_a_bt` remain for real-only
//!   call sites. QR and the eigensolver stay real (`Scalar`): retractions
//!   that need them have no complex engine, which is the paper's point.
//! - Batch parallelism lives in [`BatchMat`] (`batch` module): a `(B, p, n)`
//!   group of small matrices is stepped by sharding the *batch* across
//!   workers, never by spawning inside a single small product.
//! - Kernel dispatch lives in [`StepKernel`] (`step_kernel` module): the
//!   row-level matmul primitives AND the fused single-pass POGO/Landing
//!   steps are trait methods, with a portable field-generic implementation
//!   and AVX2/NEON microkernels (`simd` module) selected once at startup
//!   per element type — all bit-identical by contract, so selection is
//!   invisible to everything above.

mod batch;
mod complexmat;
mod eig;
mod mat;
mod matmul;
mod norms;
mod polar;
mod qr;
mod scalar;
mod simd;
mod step_kernel;

pub use batch::{
    batch_a_bh, batch_a_bh_into, batch_a_bt, batch_a_bt_into, batch_ah_b, batch_ah_b_into,
    batch_at_b, batch_at_b_into, batch_matmul, batch_matmul_into, for_each_mat_fused,
    fused_step_flops, fused_worth_parallelizing, BatchMat,
};
pub use complexmat::CMat;
pub use eig::{sym_eig, with_spectrum, SymEig};
pub use mat::Mat;
pub use matmul::{
    gemm, gemm_into, matmul, matmul_a_bh, matmul_a_bh_into, matmul_a_bt, matmul_a_bt_into,
    matmul_ah_b, matmul_ah_b_into, matmul_at_b, matmul_at_b_into, matmul_into, Op,
};
pub use step_kernel::{
    shape_class, with_step_scratch, KernelChoice, LandingParams, PogoLambda, StepKernel,
    StepScratch, PORTABLE,
};
pub use norms::{frob_norm, spectral_norm_est};
pub use polar::{polar_project, polar_project_complex, PolarOpts};
pub use qr::{qr_retract_rows, qr_thin};
pub use scalar::{Complex, Field, Scalar};

/// Single-precision matrix (the default experiment dtype, as in the paper).
pub type MatF = Mat<f32>;
/// Double-precision matrix (used by the Fig. C.1 precision ablation).
pub type MatD = Mat<f64>;
/// Single-precision complex matrix (unitary / complex-Stiefel experiments).
pub type CMatF = CMat<f32>;
/// Double-precision complex matrix.
pub type CMatD = CMat<f64>;
/// Batched complex tensor: `(B, p, n)` unitary shape groups.
pub type CBatchMat<S> = BatchMat<Complex<S>>;
