//! Dense linear-algebra substrate.
//!
//! The offline registry has no BLAS/LAPACK binding and no `ndarray`, so the
//! whole reproduction stands on this module: a row-major dense matrix type
//! generic over `f32`/`f64`, cache-blocked threaded matrix multiplication,
//! Householder QR, a Jacobi symmetric eigensolver, Newton–Schulz polar
//! decomposition, and a complex matrix type built from pairs of real ones.
//!
//! Design notes:
//! - Row-major storage everywhere (matches the HLO/XLA literal layout used
//!   by the runtime, so buffers cross the PJRT boundary without copies).
//! - The paper's matrices are *wide row-orthogonal* `X ∈ R^{p×n}`, `p ≤ n`,
//!   with `X Xᵀ = I_p`; helper names follow that convention (`gram(X)` is
//!   the small `p×p` product `X Xᵀ`).
//! - Retraction-based baselines (RGD, RSDM) run entirely on this substrate,
//!   which is the point the paper makes: QR does not map to accelerators,
//!   matmuls do.
//! - Batch parallelism lives in [`BatchMat`] (`batch` module): a `(B, p, n)`
//!   group of small matrices is stepped by sharding the *batch* across
//!   workers, never by spawning inside a single small product.

mod batch;
mod complexmat;
mod eig;
mod mat;
mod matmul;
mod norms;
mod polar;
mod qr;
mod scalar;

pub use batch::{
    batch_a_bt, batch_a_bt_into, batch_at_b, batch_at_b_into, batch_matmul,
    batch_matmul_into, BatchMat,
};
pub use complexmat::CMat;
pub use eig::{sym_eig, with_spectrum, SymEig};
pub use mat::Mat;
pub use matmul::{matmul, matmul_at_b, matmul_a_bt, matmul_into, matmul_a_bt_into, matmul_at_b_into};
pub use norms::{frob_norm, spectral_norm_est};
pub use polar::{polar_project, polar_project_complex, PolarOpts};
pub use qr::{qr_thin, qr_retract_rows};
pub use scalar::Scalar;

/// Single-precision matrix (the default experiment dtype, as in the paper).
pub type MatF = Mat<f32>;
/// Double-precision matrix (used by the Fig. C.1 precision ablation).
pub type MatD = Mat<f64>;
/// Single-precision complex matrix (unitary / complex-Stiefel experiments).
pub type CMatF = CMat<f32>;
