//! `StepKernel` — the kernel-dispatch layer under the batched engine.
//!
//! A [`StepKernel`] owns two things:
//!
//! 1. **The three row-range product primitives** (`mm_rows` / `ah_b_rows`
//!    / `a_bh_rows`) that every matmul in the crate bottoms out in. The
//!    [`PortableKernel`] delegates to the field-generic serial kernels in
//!    [`super::matmul`]; the arch kernels in [`super::simd`] override them
//!    with explicit AVX2 / NEON microkernels for `f32`/`f64`.
//! 2. **The fused per-matrix step** ([`StepKernel::pogo_step`] /
//!    [`StepKernel::landing_step`]): the whole POGO (Alg. 1) or Landing
//!    update — gram, relative-gradient update, retraction/landing
//!    correction — executed as one sweep over a single `p×n` batch
//!    element while it is hot in L1/L2, instead of the batched engine's
//!    historical 5 full passes over the `(B, p, n)` buffer. The provided
//!    implementations are built on the row primitives, so an arch kernel
//!    gets the fused+SIMD combination for free.
//!
//! **Selection** is per element type and process-wide:
//! [`Field::step_kernel`] returns the kernel chosen once at first use —
//! AVX2 on `x86_64`, NEON on `aarch64` (both runtime-detected, always
//! compiled on their arch), portable everywhere else and for complex
//! elements. `POGO_STEP_KERNEL=portable` forces the scalar fallback,
//! which is how CI keeps the portable path green on feature-poor runners.
//!
//! **Determinism contract.** Kernel selection must never change results:
//! the SIMD microkernels perform the *same arithmetic in the same order*
//! as the portable kernels (vector lanes map 1:1 onto the portable
//! accumulators; multiply-then-add, never FMA-contracted, because a fused
//! multiply-add rounds once where the portable kernel rounds twice). The
//! fused steps reuse the identical elementwise update order as the 5-pass
//! composition in `optim/batched.rs`. Both invariants together are what
//! let the parity suite (`tests/fused_parity.rs`) assert *exact* equality
//! between fused and naive paths on any machine, and what keeps serve's
//! bit-identical-replay guarantee independent of the host's ISA.

use super::matmul;
use super::scalar::{Field, Scalar};
use std::ops::Range;
use std::sync::OnceLock;

/// How a [`crate::optim::batched::BatchedHost`] executes its update —
/// round-trips through `OptimizerSpec` JSON as `"kernel"`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelChoice {
    /// Fused single-pass step where a fused rule exists (POGO, Landing,
    /// LandingPC); the 5-pass composition otherwise. The default.
    #[default]
    Auto,
    /// Force the fused single-pass step (errors never arise: rules
    /// without a fused form simply keep their composition).
    Fused,
    /// Force the historical 5-pass `BatchMat` composition.
    Naive,
}

impl KernelChoice {
    pub fn name(self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Fused => "fused",
            KernelChoice::Naive => "naive",
        }
    }

    pub fn parse(s: &str) -> Option<KernelChoice> {
        match s {
            "auto" => Some(KernelChoice::Auto),
            "fused" => Some(KernelChoice::Fused),
            "naive" => Some(KernelChoice::Naive),
            _ => None,
        }
    }
}

/// Coarse shape class of a `p×n` batch element, used as the low-arity
/// `shape` label on the per-step latency histogram
/// (`crate::obs::hist::STEP_SECONDS`) — labeling by exact `(p, n)` would
/// make series cardinality unbounded. Bounds follow the paper's regimes:
/// `tiny` covers Fig. 1's 3×3 kernels, `small` the 16×16 attention heads,
/// `medium` O-ViT-sized blocks, `large` everything beyond.
pub fn shape_class(p: usize, n: usize) -> &'static str {
    match p * n {
        0..=64 => "tiny",
        65..=1024 => "small",
        1025..=16384 => "medium",
        _ => "large",
    }
}

/// Per-matrix λ policy for the fused POGO step.
pub enum PogoLambda<'a, E: Field> {
    /// Fixed normal-step size (the paper's λ = ½ default).
    Const(f64),
    /// Solve for λ per matrix from the `p×p` gram residual `C = MMᴴ − I`
    /// (row-major slice). The closure lives in `optim` (quartic solver);
    /// keeping it a callback keeps `linalg` free of optimizer deps.
    Solve(&'a (dyn Fn(&[E], usize) -> f64 + Sync)),
}

/// Hyperparameters of the fused Landing step (one struct for Landing and
/// LandingPC — `normalize_grad` is what distinguishes them).
#[derive(Clone, Copy, Debug)]
pub struct LandingParams {
    pub eta: f64,
    pub attraction: f64,
    pub eps_ball: f64,
    pub safeguard: bool,
    pub normalize_grad: bool,
}

/// Per-worker scratch for the fused steps: every intermediate of one
/// per-matrix update, allocated once per worker thread and reused across
/// its whole batch chunk (the 5-pass path allocates B-sized tensors per
/// pass; this is `O(p·n)` per worker, resident in L1/L2).
pub struct StepScratch<E: Field> {
    /// `p×p`: gram `X Xᴴ` (Landing reuses it in place as `XXᴴ − I`).
    xxh: Vec<E>,
    /// `p×p`: cross gram `X Gᴴ`.
    xgh: Vec<E>,
    /// `p×p`: POGO's normal-step residual `M Mᴴ − I`.
    c: Vec<E>,
    /// `p×n`: `(XXᴴ)G` (Landing reuses it in place as `R`).
    a1: Vec<E>,
    /// `p×n`: `(XGᴴ)X`.
    a2: Vec<E>,
    /// `p×n`: POGO's `C·M` / Landing's normal gradient `(XXᴴ−I)X`.
    bmat: Vec<E>,
    /// `p×n`: normalized-gradient buffer (LandingPC only).
    gbuf: Vec<E>,
}

impl<E: Field> StepScratch<E> {
    pub fn new(p: usize, n: usize) -> Self {
        StepScratch {
            xxh: vec![E::ZERO; p * p],
            xgh: vec![E::ZERO; p * p],
            c: vec![E::ZERO; p * p],
            a1: vec![E::ZERO; p * n],
            a2: vec![E::ZERO; p * n],
            bmat: vec![E::ZERO; p * n],
            gbuf: vec![E::ZERO; p * n],
        }
    }
}

/// Run `f` with this thread's [`StepScratch`] for `(E, p, n)`. The slot is
/// allocated on first use and parked in a keyed thread-local arena, so
/// resident pool workers (which persist across steps) pay the allocation
/// exactly once — the fused batched step's steady state touches no heap.
/// Under `POGO_POOL=spawn`, worker threads die after every call and the
/// arena re-allocates each step; that delta is part of what
/// `benches/pool_dispatch.rs` measures.
pub fn with_step_scratch<E: Field, R>(
    p: usize,
    n: usize,
    f: impl FnOnce(&mut StepScratch<E>) -> R,
) -> R {
    crate::util::pool::with_scratch(p, n, || StepScratch::<E>::new(p, n), f)
}

/// Sequential squared Frobenius norm of a buffer — same accumulation
/// order as `BatchMat::norm_sq_per_mat` / `Mat::norm_sq`, which the
/// fused-vs-naive parity contract depends on.
#[inline]
fn frob_sq<E: Field>(v: &[E]) -> E::Real {
    let mut acc = <E::Real as Field>::ZERO;
    for &x in v {
        acc += x.abs_sq();
    }
    acc
}

/// The kernel-dispatch trait. Required methods are the three serial
/// row-range product primitives (identical contracts to the free
/// functions in [`super::matmul`]); the fused per-matrix steps are
/// provided on top of them.
pub trait StepKernel<E: Field>: Send + Sync {
    /// Kernel name for reports (`"portable"`, `"avx2"`, `"neon"`).
    fn name(&self) -> &'static str;

    /// `C = A·B` rows `rows` (A: m×k, B: k×n; `c_chunk` pre-zeroed).
    fn mm_rows(&self, a: &[E], b: &[E], rows: Range<usize>, c_chunk: &mut [E], k: usize, n: usize);

    /// `C = Aᴴ·B` rows `rows` (A: k×m, B: k×n; `c_chunk` pre-zeroed).
    #[allow(clippy::too_many_arguments)]
    fn ah_b_rows(
        &self,
        a: &[E],
        b: &[E],
        rows: Range<usize>,
        c_chunk: &mut [E],
        k: usize,
        m: usize,
        n: usize,
    );

    /// `C = A·Bᴴ` rows `rows` (A: m×k, B: n×k; assignment, no pre-zero).
    fn a_bh_rows(&self, a: &[E], b: &[E], rows: Range<usize>, c_chunk: &mut [E], k: usize, n: usize);

    /// Fused POGO step (Alg. 1) on one `p×n` matrix, in place:
    ///
    /// ```text
    /// M  = X − η·½((X Xᴴ)G − (X Gᴴ)X)      (relative-gradient update)
    /// X⁺ = M − λ(M Mᴴ − I)M                 (proximal normal step)
    /// ```
    ///
    /// Returns the λ applied. Identical elementwise arithmetic, in the
    /// identical order, to the 5-pass batched composition — the parity
    /// suite asserts exact equality, so any edit here must keep both
    /// paths in lockstep.
    fn pogo_step(
        &self,
        x: &mut [E],
        g: &[E],
        p: usize,
        n: usize,
        eta: f64,
        lambda: &PogoLambda<'_, E>,
        scratch: &mut StepScratch<E>,
    ) -> f64 {
        let StepScratch { xxh, xgh, c, a1, a2, bmat, .. } = scratch;
        // Grams: X Xᴴ and X Gᴴ (p×p each; a_bh assigns, no zeroing).
        self.a_bh_rows(&*x, &*x, 0..p, xxh, n, p);
        self.a_bh_rows(&*x, g, 0..p, xgh, n, p);
        // A1 = (X Xᴴ)·G ; A2 = (X Gᴴ)·X.
        a1.fill(E::ZERO);
        self.mm_rows(xxh, g, 0..p, a1, p, n);
        a2.fill(E::ZERO);
        self.mm_rows(xgh, &*x, 0..p, a2, p, n);
        // M = X − η·½ A1 + η·½ A2, in place over x (two axpys, same order
        // as the batched path).
        let c1 = E::from_f64(-0.5 * eta);
        let c2 = E::from_f64(0.5 * eta);
        for (xv, &av) in x.iter_mut().zip(a1.iter()) {
            *xv += c1 * av;
        }
        for (xv, &av) in x.iter_mut().zip(a2.iter()) {
            *xv += c2 * av;
        }
        // C = M Mᴴ − I ; B = C·M.
        self.a_bh_rows(&*x, &*x, 0..p, c, n, p);
        for d in 0..p {
            c[d * p + d] -= E::ONE;
        }
        bmat.fill(E::ZERO);
        self.mm_rows(c, &*x, 0..p, bmat, p, n);
        let lam = match lambda {
            PogoLambda::Const(l) => *l,
            PogoLambda::Solve(f) => f(c, p),
        };
        let al = E::from_f64(-lam);
        for (xv, &bv) in x.iter_mut().zip(bmat.iter()) {
            *xv += al * bv;
        }
        lam
    }

    /// Fused Landing step on one `p×n` matrix, in place:
    ///
    /// ```text
    /// R  = ½((X Xᴴ)G − (X Gᴴ)X)     (relative gradient)
    /// ∇N = (X Xᴴ − I)X              (normal/attraction gradient)
    /// X⁺ = X − η̃(R + λ∇N)           (η̃ safeguarded per matrix)
    /// ```
    ///
    /// Returns the safeguarded η̃ applied. Same f64 safeguard formula and
    /// elementwise order as the 5-pass batched composition (exact-parity
    /// contract, as for [`StepKernel::pogo_step`]).
    fn landing_step(
        &self,
        x: &mut [E],
        g: &[E],
        p: usize,
        n: usize,
        params: &LandingParams,
        scratch: &mut StepScratch<E>,
    ) -> f64 {
        let StepScratch { xxh, xgh, a1, a2, bmat, gbuf, .. } = scratch;
        // Optional per-matrix gradient normalization (LandingPC). Same
        // arithmetic as the batched `norm_sq_per_mat` → `scale_per_mat`
        // sequence.
        let g: &[E] = if params.normalize_grad {
            let ns = frob_sq(g);
            let nrm = Field::sqrt(ns).to_f64().max(1e-30);
            let alpha = E::from_f64(1.0 / nrm);
            for (dst, &v) in gbuf.iter_mut().zip(g.iter()) {
                *dst = v * alpha;
            }
            gbuf
        } else {
            g
        };
        self.a_bh_rows(&*x, &*x, 0..p, xxh, n, p);
        self.a_bh_rows(&*x, g, 0..p, xgh, n, p);
        a1.fill(E::ZERO);
        self.mm_rows(xxh, g, 0..p, a1, p, n);
        a2.fill(E::ZERO);
        self.mm_rows(xgh, &*x, 0..p, a2, p, n);
        // R = ½(A1 − A2), reusing a1 (sub then scale, batched order).
        let half = E::from_f64(0.5);
        for (rv, &av) in a1.iter_mut().zip(a2.iter()) {
            *rv = (*rv - av) * half;
        }
        // H = X Xᴴ − I in place over xxh; ∇N = H·X.
        for d in 0..p {
            xxh[d * p + d] -= E::ONE;
        }
        bmat.fill(E::ZERO);
        self.mm_rows(xxh, &*x, 0..p, bmat, p, n);
        // Safeguarded step size — the identical f64 formula of the 5-pass
        // path (and the per-matrix loop engine).
        let h_ns = frob_sq(xxh);
        let r_ns = frob_sq(a1);
        let n_ns = frob_sq(bmat);
        let lam = params.attraction;
        let d = Field::sqrt(h_ns).to_f64();
        let lam_sq = r_ns.to_f64() + lam * lam * n_ns.to_f64();
        let eta_i = if params.safeguard && lam_sq > 0.0 {
            let slack = (params.eps_ball - d).max(0.0);
            let b = lam * d * (1.0 - d).max(0.0);
            let safe = (b + (b * b + lam_sq * slack).sqrt()) / lam_sq;
            let cap = if lam > 0.0 { 0.5 / lam } else { f64::INFINITY };
            params.eta.min(safe).min(cap)
        } else {
            params.eta
        };
        let a_r = E::from_f64(-eta_i);
        let a_n = E::from_f64(-eta_i * lam);
        for (xv, &rv) in x.iter_mut().zip(a1.iter()) {
            *xv += a_r * rv;
        }
        for (xv, &nv) in x.iter_mut().zip(bmat.iter()) {
            *xv += a_n * nv;
        }
        eta_i
    }
}

/// The field-generic reference kernel: delegates the row primitives to
/// the serial kernels in [`super::matmul`] (the exact code every engine
/// ran before this dispatch layer existed). Serves all `Field` types —
/// it is the only kernel for complex elements, and the runtime fallback
/// (or `POGO_STEP_KERNEL=portable` override) for `f32`/`f64`.
pub struct PortableKernel;

/// The portable kernel instance (`&PORTABLE` coerces to
/// `&'static dyn StepKernel<E>` for any field).
pub static PORTABLE: PortableKernel = PortableKernel;

impl<E: Field> StepKernel<E> for PortableKernel {
    fn name(&self) -> &'static str {
        "portable"
    }

    fn mm_rows(&self, a: &[E], b: &[E], rows: Range<usize>, c_chunk: &mut [E], k: usize, n: usize) {
        matmul::mm_rows(a, b, rows, c_chunk, k, n);
    }

    fn ah_b_rows(
        &self,
        a: &[E],
        b: &[E],
        rows: Range<usize>,
        c_chunk: &mut [E],
        k: usize,
        m: usize,
        n: usize,
    ) {
        matmul::ah_b_rows(a, b, rows, c_chunk, k, m, n);
    }

    fn a_bh_rows(&self, a: &[E], b: &[E], rows: Range<usize>, c_chunk: &mut [E], k: usize, n: usize) {
        matmul::a_bh_rows(a, b, rows, c_chunk, k, n);
    }
}

/// True when `POGO_STEP_KERNEL` forces the scalar fallback (read once;
/// the CI portable leg sets it for a whole test run).
fn forced_portable() -> bool {
    static FORCED: OnceLock<bool> = OnceLock::new();
    *FORCED.get_or_init(|| {
        matches!(
            std::env::var("POGO_STEP_KERNEL").ok().as_deref(),
            Some("portable") | Some("scalar")
        )
    })
}

/// Process-wide kernel for `f32`, selected once at first use: AVX2 on
/// `x86_64`, NEON on `aarch64` (runtime-detected), portable otherwise.
pub fn select_f32() -> &'static dyn StepKernel<f32> {
    static SEL: OnceLock<&'static dyn StepKernel<f32>> = OnceLock::new();
    *SEL.get_or_init(|| {
        if forced_portable() {
            return &PORTABLE;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return &super::simd::x86::AVX2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return &super::simd::arm::NEON;
            }
        }
        &PORTABLE
    })
}

/// Process-wide kernel for `f64` (same selection policy as
/// [`select_f32`]).
pub fn select_f64() -> &'static dyn StepKernel<f64> {
    static SEL: OnceLock<&'static dyn StepKernel<f64>> = OnceLock::new();
    *SEL.get_or_init(|| {
        if forced_portable() {
            return &PORTABLE;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return &super::simd::x86::AVX2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return &super::simd::arm::NEON;
            }
        }
        &PORTABLE
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul as mm, Complex, Mat};
    use crate::rng::Rng;

    #[test]
    fn shape_classes_cover_paper_regimes() {
        assert_eq!(shape_class(3, 3), "tiny");
        assert_eq!(shape_class(8, 8), "tiny");
        assert_eq!(shape_class(16, 16), "small");
        assert_eq!(shape_class(4, 8), "tiny");
        assert_eq!(shape_class(64, 128), "medium");
        assert_eq!(shape_class(256, 512), "large");
    }

    #[test]
    fn kernel_choice_round_trips() {
        for c in [KernelChoice::Auto, KernelChoice::Fused, KernelChoice::Naive] {
            assert_eq!(KernelChoice::parse(c.name()), Some(c));
        }
        assert_eq!(KernelChoice::parse("simd"), None);
        assert_eq!(KernelChoice::default(), KernelChoice::Auto);
    }

    #[test]
    fn selected_kernels_match_portable_exactly() {
        // The determinism contract: whatever `Field::step_kernel` picked
        // on this machine, its row primitives agree with the portable
        // kernel bit-for-bit (lane-exact SIMD, no FMA contraction).
        let mut rng = Rng::seed_from_u64(11);
        let (m, k, n) = (7, 19, 13);
        let a = Mat::<f64>::randn(m, k, &mut rng);
        let b = Mat::<f64>::randn(k, n, &mut rng);
        let kern = <f64 as Field>::step_kernel();
        let mut c_sel = Mat::<f64>::zeros(m, n);
        let mut c_ref = Mat::<f64>::zeros(m, n);
        kern.mm_rows(a.as_slice(), b.as_slice(), 0..m, c_sel.as_mut_slice(), k, n);
        StepKernel::<f64>::mm_rows(
            &PORTABLE,
            a.as_slice(),
            b.as_slice(),
            0..m,
            c_ref.as_mut_slice(),
            k,
            n,
        );
        assert!(c_sel.sub(&c_ref).max_abs() == 0.0, "mm_rows ({})", kern.name());

        let at = Mat::<f64>::randn(k, m, &mut rng);
        let mut d_sel = Mat::<f64>::zeros(m, n);
        let mut d_ref = Mat::<f64>::zeros(m, n);
        kern.ah_b_rows(at.as_slice(), b.as_slice(), 0..m, d_sel.as_mut_slice(), k, m, n);
        StepKernel::<f64>::ah_b_rows(
            &PORTABLE,
            at.as_slice(),
            b.as_slice(),
            0..m,
            d_ref.as_mut_slice(),
            k,
            m,
            n,
        );
        assert!(d_sel.sub(&d_ref).max_abs() == 0.0, "ah_b_rows ({})", kern.name());

        let bt = Mat::<f64>::randn(n, k, &mut rng);
        let mut e_sel = Mat::<f64>::zeros(m, n);
        let mut e_ref = Mat::<f64>::zeros(m, n);
        kern.a_bh_rows(a.as_slice(), bt.as_slice(), 0..m, e_sel.as_mut_slice(), k, n);
        StepKernel::<f64>::a_bh_rows(
            &PORTABLE,
            a.as_slice(),
            bt.as_slice(),
            0..m,
            e_ref.as_mut_slice(),
            k,
            n,
        );
        assert!(e_sel.sub(&e_ref).max_abs() == 0.0, "a_bh_rows ({})", kern.name());
    }

    #[test]
    fn f32_selected_kernel_matches_portable_exactly() {
        let mut rng = Rng::seed_from_u64(12);
        let (m, k, n) = (5, 23, 9);
        let a = Mat::<f32>::randn(m, k, &mut rng);
        let b = Mat::<f32>::randn(k, n, &mut rng);
        let kern = <f32 as Field>::step_kernel();
        let mut c_sel = Mat::<f32>::zeros(m, n);
        let mut c_ref = Mat::<f32>::zeros(m, n);
        kern.mm_rows(a.as_slice(), b.as_slice(), 0..m, c_sel.as_mut_slice(), k, n);
        StepKernel::<f32>::mm_rows(
            &PORTABLE,
            a.as_slice(),
            b.as_slice(),
            0..m,
            c_ref.as_mut_slice(),
            k,
            n,
        );
        assert!(c_sel.sub(&c_ref).max_abs() == 0.0, "mm_rows ({})", kern.name());

        let bt = Mat::<f32>::randn(n, k, &mut rng);
        let mut e_sel = Mat::<f32>::zeros(m, n);
        let mut e_ref = Mat::<f32>::zeros(m, n);
        kern.a_bh_rows(a.as_slice(), bt.as_slice(), 0..m, e_sel.as_mut_slice(), k, n);
        StepKernel::<f32>::a_bh_rows(
            &PORTABLE,
            a.as_slice(),
            bt.as_slice(),
            0..m,
            e_ref.as_mut_slice(),
            k,
            n,
        );
        assert!(e_sel.sub(&e_ref).max_abs() == 0.0, "a_bh_rows ({})", kern.name());
    }

    #[test]
    fn complex_elements_use_portable() {
        assert_eq!(<Complex<f64> as Field>::step_kernel().name(), "portable");
        assert_eq!(<Complex<f32> as Field>::step_kernel().name(), "portable");
    }

    #[test]
    fn fused_pogo_step_matches_composition() {
        // Drive the portable kernel's fused step directly against a
        // hand-rolled 5-product composition on one matrix; exact match.
        let mut rng = Rng::seed_from_u64(13);
        let (p, n) = (4, 9);
        let x0 = crate::manifold::stiefel::random_point_t::<f64>(p, n, &mut rng);
        let g = Mat::<f64>::randn(p, n, &mut rng).scale(0.3);
        let eta = 0.2;

        // Composition (same ops the batched naive path performs).
        let xxh = mm::matmul_a_bh(&x0, &x0);
        let xgh = mm::matmul_a_bh(&x0, &g);
        let a1 = mm::matmul(&xxh, &g);
        let a2 = mm::matmul(&xgh, &x0);
        let mut m = x0.clone();
        m.axpy(-0.5 * eta, &a1);
        m.axpy(0.5 * eta, &a2);
        let mut c = mm::matmul_a_bh(&m, &m);
        c.sub_eye_inplace();
        let bmat = mm::matmul(&c, &m);
        m.axpy(-0.5, &bmat);

        // Fused.
        let mut xf = x0.clone();
        let mut scratch = StepScratch::new(p, n);
        let lam = PORTABLE.pogo_step(
            xf.as_mut_slice(),
            g.as_slice(),
            p,
            n,
            eta,
            &PogoLambda::Const(0.5),
            &mut scratch,
        );
        assert_eq!(lam, 0.5);
        assert!(xf.sub(&m).max_abs() == 0.0, "fused != composition");
    }
}
