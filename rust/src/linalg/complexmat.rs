//! Complex dense matrices as (re, im) pairs of real matrices.
//!
//! The complex-Stiefel (unitary) experiments — squared unitary PCs / the
//! Born-machine MPS of Fig. 8 — need `X ∈ C^{p×n}` with `X X^H = I_p`.
//! Rather than introduce a complex scalar into every generic signature, a
//! `CMat` carries two real `Mat`s and implements the handful of operations
//! the unitary orthoptimizers need. Products expand to 4 real matmuls,
//! reusing the threaded real substrate. This split representation is also
//! exactly how complex parameters cross the PJRT boundary (two f32
//! literals), so no conversion happens at the runtime edge.

use super::mat::Mat;
use super::matmul;
use super::scalar::Scalar;
use crate::rng::Rng;

/// Dense complex matrix: `A = re + i·im`, both row-major `rows × cols`.
#[derive(Clone, Debug, PartialEq)]
pub struct CMat<S: Scalar> {
    pub re: Mat<S>,
    pub im: Mat<S>,
}

impl<S: Scalar> CMat<S> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        CMat { re: Mat::zeros(rows, cols), im: Mat::zeros(rows, cols) }
    }

    pub fn eye(n: usize) -> Self {
        CMat { re: Mat::eye(n), im: Mat::zeros(n, n) }
    }

    pub fn from_parts(re: Mat<S>, im: Mat<S>) -> Self {
        assert_eq!(re.shape(), im.shape(), "re/im shape mismatch");
        CMat { re, im }
    }

    /// i.i.d. complex standard Gaussian (re, im each N(0, 1/2) so that
    /// E|z|² = 1).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let s = std::f64::consts::FRAC_1_SQRT_2;
        let mut re = Mat::zeros(rows, cols);
        let mut im = Mat::zeros(rows, cols);
        for v in re.as_mut_slice().iter_mut() {
            *v = S::from_f64(rng.gaussian() * s);
        }
        for v in im.as_mut_slice().iter_mut() {
            *v = S::from_f64(rng.gaussian() * s);
        }
        CMat { re, im }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.re.rows()
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.re.cols()
    }
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        self.re.shape()
    }

    /// Conjugate transpose `A^H`.
    pub fn adjoint(&self) -> CMat<S> {
        CMat { re: self.re.transpose(), im: self.im.transpose().scale(-S::ONE) }
    }

    /// Complex matmul `A · B` (4 real matmuls).
    pub fn matmul(&self, b: &CMat<S>) -> CMat<S> {
        let rr = matmul::matmul(&self.re, &b.re);
        let ii = matmul::matmul(&self.im, &b.im);
        let ri = matmul::matmul(&self.re, &b.im);
        let ir = matmul::matmul(&self.im, &b.re);
        CMat { re: rr.sub(&ii), im: ri.add(&ir) }
    }

    /// `A · B^H` without materializing the adjoint:
    /// re = Ar·Brᵀ + Ai·Biᵀ, im = Ai·Brᵀ − Ar·Biᵀ.
    pub fn matmul_a_bh(&self, b: &CMat<S>) -> CMat<S> {
        let rr = matmul::matmul_a_bt(&self.re, &b.re);
        let ii = matmul::matmul_a_bt(&self.im, &b.im);
        let ir = matmul::matmul_a_bt(&self.im, &b.re);
        let ri = matmul::matmul_a_bt(&self.re, &b.im);
        CMat { re: rr.add(&ii), im: ir.sub(&ri) }
    }

    /// `A^H · B`: re = Arᵀ·Br + Aiᵀ·Bi, im = Arᵀ·Bi − Aiᵀ·Br.
    pub fn matmul_ah_b(&self, b: &CMat<S>) -> CMat<S> {
        let rr = matmul::matmul_at_b(&self.re, &b.re);
        let ii = matmul::matmul_at_b(&self.im, &b.im);
        let ri = matmul::matmul_at_b(&self.re, &b.im);
        let ir = matmul::matmul_at_b(&self.im, &b.re);
        CMat { re: rr.add(&ii), im: ri.sub(&ir) }
    }

    pub fn add(&self, b: &CMat<S>) -> CMat<S> {
        CMat { re: self.re.add(&b.re), im: self.im.add(&b.im) }
    }

    pub fn sub(&self, b: &CMat<S>) -> CMat<S> {
        CMat { re: self.re.sub(&b.re), im: self.im.sub(&b.im) }
    }

    /// Scale by a *real* scalar.
    pub fn scale_re(&self, alpha: S) -> CMat<S> {
        CMat { re: self.re.scale(alpha), im: self.im.scale(alpha) }
    }

    /// `self += alpha * other` with real alpha.
    pub fn axpy_re(&mut self, alpha: S, other: &CMat<S>) {
        self.re.axpy(alpha, &other.re);
        self.im.axpy(alpha, &other.im);
    }

    /// Subtract the identity in place (square).
    pub fn sub_eye_inplace(&mut self) {
        self.re.sub_eye_inplace();
    }

    /// Skew-Hermitian part `(A − A^H)/2` (square).
    pub fn skew_h(&self) -> CMat<S> {
        let ah = self.adjoint();
        let half = S::from_f64(0.5);
        CMat { re: self.re.sub(&ah.re).scale(half), im: self.im.sub(&ah.im).scale(half) }
    }

    /// Frobenius norm (`sqrt(Σ |a_ij|²)`).
    pub fn norm(&self) -> S {
        (self.re.norm_sq() + self.im.norm_sq()).sqrt()
    }

    /// Squared Frobenius norm.
    pub fn norm_sq(&self) -> S {
        self.re.norm_sq() + self.im.norm_sq()
    }

    /// Real part of the Frobenius inner product `Re Tr(B^H A)`.
    pub fn dot_re(&self, b: &CMat<S>) -> S {
        self.re.dot(&b.re) + self.im.dot(&b.im)
    }

    /// Spectral norm estimate via the real embedding `[re −im; im re]`'s
    /// action: power iteration on `A A^H`.
    pub fn spectral_norm_est(&self, iters: usize) -> f64 {
        let p = self.rows();
        let g = self.matmul_a_bh(self); // p×p Hermitian PSD
        let mut vr = vec![1.0f64; p];
        let mut vi = vec![0.0f64; p];
        let mut lam = 0.0f64;
        for _ in 0..iters {
            let mut wr = vec![0.0f64; p];
            let mut wi = vec![0.0f64; p];
            for i in 0..p {
                let (gr, gi) = (g.re.row(i), g.im.row(i));
                let (mut ar, mut ai) = (0.0f64, 0.0f64);
                for j in 0..p {
                    let (grj, gij) = (gr[j].to_f64(), gi[j].to_f64());
                    ar += grj * vr[j] - gij * vi[j];
                    ai += grj * vi[j] + gij * vr[j];
                }
                wr[i] = ar;
                wi[i] = ai;
            }
            let norm = wr
                .iter()
                .zip(&wi)
                .map(|(r, i)| r * r + i * i)
                .sum::<f64>()
                .sqrt();
            if norm == 0.0 {
                return 0.0;
            }
            lam = norm;
            for j in 0..p {
                vr[j] = wr[j] / norm;
                vi[j] = wi[j] / norm;
            }
        }
        lam.sqrt()
    }

    /// `‖X X^H − I‖_F` — distance proxy to the complex Stiefel manifold.
    pub fn stiefel_distance(&self) -> f64 {
        let mut g = self.matmul_a_bh(self);
        g.sub_eye_inplace();
        g.norm().to_f64()
    }

    /// True if all entries are finite.
    pub fn all_finite(&self) -> bool {
        self.re.all_finite() && self.im.all_finite()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    type C = CMat<f64>;

    #[test]
    fn adjoint_involution() {
        let mut rng = Rng::seed_from_u64(0);
        let a = C::randn(4, 7, &mut rng);
        assert_eq!(a.adjoint().adjoint(), a);
    }

    #[test]
    fn matmul_matches_manual_small() {
        // (1+2i)(3+4i) = 3+4i+6i+8i² = -5+10i
        let a = C::from_parts(Mat::from_vec(1, 1, vec![1.0]), Mat::from_vec(1, 1, vec![2.0]));
        let b = C::from_parts(Mat::from_vec(1, 1, vec![3.0]), Mat::from_vec(1, 1, vec![4.0]));
        let c = a.matmul(&b);
        assert!((c.re[(0, 0)] + 5.0).abs() < 1e-12);
        assert!((c.im[(0, 0)] - 10.0).abs() < 1e-12);
    }

    #[test]
    fn a_bh_consistent_with_adjoint_matmul() {
        let mut rng = Rng::seed_from_u64(1);
        let a = C::randn(3, 8, &mut rng);
        let b = C::randn(5, 8, &mut rng);
        let fast = a.matmul_a_bh(&b);
        let slow = a.matmul(&b.adjoint());
        assert!(fast.sub(&slow).norm() < 1e-10);
    }

    #[test]
    fn ah_b_consistent_with_adjoint_matmul() {
        let mut rng = Rng::seed_from_u64(2);
        let a = C::randn(8, 3, &mut rng);
        let b = C::randn(8, 5, &mut rng);
        let fast = a.matmul_ah_b(&b);
        let slow = a.adjoint().matmul(&b);
        assert!(fast.sub(&slow).norm() < 1e-10);
    }

    #[test]
    fn skew_h_is_anti_hermitian() {
        let mut rng = Rng::seed_from_u64(3);
        let s = C::randn(6, 6, &mut rng).skew_h();
        let sum = s.add(&s.adjoint());
        assert!(sum.norm() < 1e-12);
    }

    #[test]
    fn identity_unitary_distance_zero() {
        let i = C::eye(5);
        assert!(i.stiefel_distance() < 1e-14);
    }

    #[test]
    fn spectral_norm_of_identity() {
        let i = C::eye(4);
        let s = i.spectral_norm_est(20);
        assert!((s - 1.0).abs() < 1e-9, "s={s}");
    }
}
