//! Complex dense matrices: `CMat<S>` is just [`Mat`] over the
//! [`Complex`] field element.
//!
//! Before the `Field` abstraction this file held a hand-written `CMat`
//! with split (re, im) planes and its own 4-real-matmul product set; the
//! complex-Stiefel optimizers were a duplicated fork over it. Now the one
//! generic substrate serves both fields (paper §2, fn. 1), and this
//! module only keeps the complex-specific conveniences:
//!
//! - split-plane constructors/accessors — the PJRT boundary ships complex
//!   parameters as two real literals, so `from_parts` / `re_vec` /
//!   `im_vec` are exactly the runtime-edge conversion;
//! - the complex-Stiefel feasibility metric `‖X Xᴴ − I‖_F`.

use super::mat::Mat;
use super::matmul::matmul_a_bh;
use super::scalar::{Complex, Scalar};

/// Dense complex matrix: row-major interleaved `Complex<S>` entries.
pub type CMat<S> = Mat<Complex<S>>;

impl<S: Scalar> Mat<Complex<S>> {
    /// Build from separate real/imaginary planes (shapes must match).
    pub fn from_parts(re: Mat<S>, im: Mat<S>) -> Self {
        assert_eq!(re.shape(), im.shape(), "re/im shape mismatch");
        let (rows, cols) = re.shape();
        let data = re
            .as_slice()
            .iter()
            .zip(im.as_slice())
            .map(|(&r, &i)| Complex::new(r, i))
            .collect();
        Mat::from_vec(rows, cols, data)
    }

    /// The real plane as a standalone matrix.
    pub fn re_mat(&self) -> Mat<S> {
        let (rows, cols) = self.shape();
        Mat::from_vec(rows, cols, self.as_slice().iter().map(|z| z.re).collect())
    }

    /// The imaginary plane as a standalone matrix.
    pub fn im_mat(&self) -> Mat<S> {
        let (rows, cols) = self.shape();
        Mat::from_vec(rows, cols, self.as_slice().iter().map(|z| z.im).collect())
    }

    /// Row-major real plane (the PJRT literal payload).
    pub fn re_vec(&self) -> Vec<S> {
        self.as_slice().iter().map(|z| z.re).collect()
    }

    /// Row-major imaginary plane.
    pub fn im_vec(&self) -> Vec<S> {
        self.as_slice().iter().map(|z| z.im).collect()
    }

    /// `‖X Xᴴ − I‖_F` — distance proxy to the complex Stiefel manifold.
    pub fn stiefel_distance(&self) -> f64 {
        let mut g = matmul_a_bh(self, self);
        g.sub_eye_inplace();
        g.norm().to_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_ah_b;
    use crate::rng::Rng;

    type C = CMat<f64>;

    #[test]
    fn adjoint_involution() {
        let mut rng = Rng::seed_from_u64(0);
        let a = C::randn(4, 7, &mut rng);
        assert_eq!(a.adjoint().adjoint(), a);
    }

    #[test]
    fn planes_roundtrip() {
        let mut rng = Rng::seed_from_u64(1);
        let a = C::randn(5, 6, &mut rng);
        let back = C::from_parts(a.re_mat(), a.im_mat());
        assert_eq!(a, back);
        assert_eq!(a.re_vec(), a.re_mat().as_slice());
        assert_eq!(a.im_vec(), a.im_mat().as_slice());
    }

    #[test]
    fn skew_h_is_anti_hermitian() {
        let mut rng = Rng::seed_from_u64(3);
        let s = C::randn(6, 6, &mut rng).skew_h();
        let sum = s.add(&s.adjoint());
        assert!(sum.norm() < 1e-12);
    }

    #[test]
    fn identity_unitary_distance_zero() {
        let i = C::eye(5);
        assert!(i.stiefel_distance() < 1e-14);
    }

    #[test]
    fn dot_re_is_real_inner_product() {
        // Re Tr(Bᴴ A) computed elementwise must match the adjoint-trace
        // form.
        let mut rng = Rng::seed_from_u64(4);
        let a = C::randn(3, 5, &mut rng);
        let b = C::randn(3, 5, &mut rng);
        let fast = a.dot_re(&b);
        let tr = matmul_ah_b(&b, &a).trace();
        assert!((fast - tr.re).abs() < 1e-12, "{fast} vs {:?}", tr);
    }
}
