//! Scalar and field abstractions.
//!
//! Two layers (paper §2, fn. 1 — "all derivations extend verbatim to other
//! fields like the complex numbers"):
//!
//! - [`Field`] — a matrix *element*: the ring/involution operations the
//!   linalg substrate and the matmul-only orthoptimizers need (`conj`,
//!   `mul_conj`, real part, squared modulus). Implemented by `f32`/`f64`
//!   (identity conjugation — the real path compiles to exactly the code it
//!   had before this abstraction existed) and by [`Complex<S>`].
//! - [`Scalar`] — a *real* scalar (`Field<Real = Self>` plus ordering,
//!   `abs`, machine epsilon, bf16 truncation). Everything that is
//!   inherently real — QR, eigensolvers, norms' return values, learning
//!   rates — stays bounded by `Scalar`.
//!
//! The split is what lets POGO / Landing / SLPG be written once over
//! `Field` and instantiated on both the real and the complex Stiefel
//! manifold (see DESIGN.md).

use crate::rng::Rng;
use std::fmt::Debug;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// A field element: the operations shared by real and complex matrix
/// entries. `Div`/`sqrt` are included because elementwise Adam divides by
/// `√v̂` (the complex instantiation exists for type-uniformity; complex
/// Adam is gated off at construction by Def. 1 linearity).
pub trait Field:
    Copy
    + Clone
    + Debug
    + PartialEq
    + Default
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
{
    /// The underlying real scalar type (`Self` for real fields).
    type Real: Scalar;

    const ZERO: Self;
    const ONE: Self;
    /// Whether this field has a non-trivial conjugation (complex).
    const COMPLEX: bool;

    /// Complex conjugate (identity for real fields).
    fn conj(self) -> Self;
    /// `self · conj(other)` — the inner-product kernel of `A Bᴴ`.
    fn mul_conj(self, other: Self) -> Self;
    /// Real part.
    fn re(self) -> Self::Real;
    /// Imaginary part (zero for real fields).
    fn im(self) -> Self::Real;
    /// Squared modulus `|z|²` (the Frobenius-norm kernel).
    fn abs_sq(self) -> Self::Real;
    /// Embed a real scalar.
    fn from_re(r: Self::Real) -> Self;
    /// Embed an `f64` (real embedding; imaginary part zero).
    fn from_f64(v: f64) -> Self;
    /// Principal square root.
    fn sqrt(self) -> Self;
    /// True if every component is finite.
    fn is_finite(self) -> bool;
    /// Draw a standard Gaussian element: `N(0, 1)` for real fields; for
    /// complex, re/im each `N(0, ½)` so that `E|z|² = 1`.
    fn sample_gaussian(rng: &mut Rng) -> Self;
    /// The runtime-selected [`StepKernel`](crate::linalg::StepKernel) for
    /// this element type: an arch microkernel (AVX2/NEON) for `f32`/`f64`
    /// once feature detection succeeds, the portable kernel otherwise and
    /// for complex elements. All kernels are bit-identical by contract
    /// (see `linalg::step_kernel`), so callers may treat this as a pure
    /// perf hint.
    fn step_kernel() -> &'static dyn crate::linalg::step_kernel::StepKernel<Self>;
}

/// Real scalar: a totally-ordered [`Field`] over itself.
pub trait Scalar: Field<Real = Self> + PartialOrd {
    /// Machine epsilon of the type.
    const EPS: Self;

    fn to_f64(self) -> f64;
    fn abs(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn max_s(self, other: Self) -> Self;
    fn min_s(self, other: Self) -> Self;
    /// Truncate the mantissa to bfloat16 precision (keeps f32 exponent).
    /// Identity for f64 inputs converted via f32 path only when requested.
    fn truncate_bf16(self) -> Self;
}

macro_rules! impl_real_field {
    ($t:ty, $sel:path) => {
        impl Field for $t {
            type Real = $t;

            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;
            const COMPLEX: bool = false;

            #[inline]
            fn conj(self) -> Self {
                self
            }
            #[inline]
            fn mul_conj(self, other: Self) -> Self {
                self * other
            }
            #[inline]
            fn re(self) -> Self {
                self
            }
            #[inline]
            fn im(self) -> Self {
                0.0
            }
            #[inline]
            fn abs_sq(self) -> Self {
                self * self
            }
            #[inline]
            fn from_re(r: Self) -> Self {
                r
            }
            #[inline]
            fn from_f64(v: f64) -> Self {
                v as $t
            }
            #[inline]
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            #[inline]
            fn is_finite(self) -> bool {
                <$t>::is_finite(self)
            }
            #[inline]
            fn sample_gaussian(rng: &mut Rng) -> Self {
                rng.gaussian() as $t
            }
            #[inline]
            fn step_kernel() -> &'static dyn crate::linalg::step_kernel::StepKernel<Self> {
                $sel()
            }
        }
    };
}

impl_real_field!(f32, crate::linalg::step_kernel::select_f32);
impl_real_field!(f64, crate::linalg::step_kernel::select_f64);

impl Scalar for f32 {
    const EPS: Self = f32::EPSILON;

    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline]
    fn powi(self, n: i32) -> Self {
        f32::powi(self, n)
    }
    #[inline]
    fn max_s(self, other: Self) -> Self {
        f32::max(self, other)
    }
    #[inline]
    fn min_s(self, other: Self) -> Self {
        f32::min(self, other)
    }
    #[inline]
    fn truncate_bf16(self) -> Self {
        f32::from_bits(self.to_bits() & 0xFFFF_0000)
    }
}

impl Scalar for f64 {
    const EPS: Self = f64::EPSILON;

    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline]
    fn powi(self, n: i32) -> Self {
        f64::powi(self, n)
    }
    #[inline]
    fn max_s(self, other: Self) -> Self {
        f64::max(self, other)
    }
    #[inline]
    fn min_s(self, other: Self) -> Self {
        f64::min(self, other)
    }
    #[inline]
    fn truncate_bf16(self) -> Self {
        // bf16 truncation is defined through the f32 path; for f64 we go
        // f64 -> f32 -> bf16 -> f64, matching what a bf16 matmul unit sees.
        (f32::from_bits((self as f32).to_bits() & 0xFFFF_0000)) as f64
    }
}

// ---------------------------------------------------------------------------
// Complex field element.
// ---------------------------------------------------------------------------

/// Complex number over a real scalar, stored interleaved as matrix
/// elements (`Mat<Complex<S>>` is the crate's complex matrix type — see
/// `linalg::CMat`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Complex<S> {
    pub re: S,
    pub im: S,
}

impl<S: Scalar> Complex<S> {
    #[inline]
    pub fn new(re: S, im: S) -> Self {
        Complex { re, im }
    }

    /// Modulus `|z|`.
    #[inline]
    pub fn modulus(self) -> S {
        Field::sqrt(self.re * self.re + self.im * self.im)
    }
}

impl<S: Scalar> Add for Complex<S> {
    type Output = Self;
    #[inline]
    fn add(self, o: Self) -> Self {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }
}

impl<S: Scalar> Sub for Complex<S> {
    type Output = Self;
    #[inline]
    fn sub(self, o: Self) -> Self {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }
}

impl<S: Scalar> Mul for Complex<S> {
    type Output = Self;
    #[inline]
    fn mul(self, o: Self) -> Self {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl<S: Scalar> Div for Complex<S> {
    type Output = Self;
    #[inline]
    fn div(self, o: Self) -> Self {
        // z / w = z·conj(w) / |w|².
        let d = o.re * o.re + o.im * o.im;
        Complex {
            re: (self.re * o.re + self.im * o.im) / d,
            im: (self.im * o.re - self.re * o.im) / d,
        }
    }
}

impl<S: Scalar> Neg for Complex<S> {
    type Output = Self;
    #[inline]
    fn neg(self) -> Self {
        Complex { re: -self.re, im: -self.im }
    }
}

impl<S: Scalar> AddAssign for Complex<S> {
    #[inline]
    fn add_assign(&mut self, o: Self) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl<S: Scalar> SubAssign for Complex<S> {
    #[inline]
    fn sub_assign(&mut self, o: Self) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl<S: Scalar> MulAssign for Complex<S> {
    #[inline]
    fn mul_assign(&mut self, o: Self) {
        *self = *self * o;
    }
}

impl<S: Scalar> DivAssign for Complex<S> {
    #[inline]
    fn div_assign(&mut self, o: Self) {
        *self = *self / o;
    }
}

impl<S: Scalar> Sum for Complex<S> {
    fn sum<I: Iterator<Item = Self>>(iter: I) -> Self {
        iter.fold(Complex { re: S::ZERO, im: S::ZERO }, Add::add)
    }
}

impl<S: Scalar> Field for Complex<S> {
    type Real = S;

    const ZERO: Self = Complex { re: S::ZERO, im: S::ZERO };
    const ONE: Self = Complex { re: S::ONE, im: S::ZERO };
    const COMPLEX: bool = true;

    #[inline]
    fn conj(self) -> Self {
        Complex { re: self.re, im: -self.im }
    }
    #[inline]
    fn mul_conj(self, other: Self) -> Self {
        Complex {
            re: self.re * other.re + self.im * other.im,
            im: self.im * other.re - self.re * other.im,
        }
    }
    #[inline]
    fn re(self) -> S {
        self.re
    }
    #[inline]
    fn im(self) -> S {
        self.im
    }
    #[inline]
    fn abs_sq(self) -> S {
        self.re * self.re + self.im * self.im
    }
    #[inline]
    fn from_re(r: S) -> Self {
        Complex { re: r, im: S::ZERO }
    }
    #[inline]
    fn from_f64(v: f64) -> Self {
        Complex { re: S::from_f64(v), im: S::ZERO }
    }
    #[inline]
    fn sqrt(self) -> Self {
        // Principal branch: Re √z ≥ 0. With r = |z|,
        // √z = (√((r+a)/2), sign(b)·√((r−a)/2)).
        let half = S::from_f64(0.5);
        let r = self.modulus();
        let gamma = Field::sqrt(((r + self.re) * half).max_s(S::ZERO));
        let delta = Field::sqrt(((r - self.re) * half).max_s(S::ZERO));
        let delta = if self.im < S::ZERO { -delta } else { delta };
        Complex { re: gamma, im: delta }
    }
    #[inline]
    fn is_finite(self) -> bool {
        Field::is_finite(self.re) && Field::is_finite(self.im)
    }
    #[inline]
    fn sample_gaussian(rng: &mut Rng) -> Self {
        let s = std::f64::consts::FRAC_1_SQRT_2;
        Complex {
            re: S::from_f64(rng.gaussian() * s),
            im: S::from_f64(rng.gaussian() * s),
        }
    }
    #[inline]
    fn step_kernel() -> &'static dyn crate::linalg::step_kernel::StepKernel<Self> {
        // The arch microkernels cover real lanes only; complex elements
        // always run the field-generic portable kernel.
        &crate::linalg::step_kernel::PORTABLE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    type C = Complex<f64>;

    #[test]
    fn bf16_truncation_drops_low_mantissa() {
        let x: f32 = 1.0 + f32::EPSILON * 100.0;
        let t = x.truncate_bf16();
        assert!(t.to_bits() & 0xFFFF == 0);
        assert!((t - x).abs() < 1e-2);
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(<f32 as Field>::from_f64(1.5).to_f64(), 1.5);
        assert_eq!(<f64 as Field>::from_f64(1.5).to_f64(), 1.5);
    }

    #[test]
    fn real_conjugation_is_identity() {
        assert_eq!(Field::conj(2.5f64), 2.5);
        assert_eq!(2.0f32.mul_conj(3.0), 6.0);
        assert_eq!(Field::abs_sq(-3.0f64), 9.0);
        assert!(!f64::COMPLEX && Complex::<f64>::COMPLEX);
    }

    #[test]
    fn complex_arithmetic() {
        // (1+2i)(3+4i) = -5+10i
        let z = C::new(1.0, 2.0) * C::new(3.0, 4.0);
        assert_eq!(z, C::new(-5.0, 10.0));
        // Division inverts multiplication.
        let back = z / C::new(3.0, 4.0);
        assert!((back.re - 1.0).abs() < 1e-12 && (back.im - 2.0).abs() < 1e-12);
        // conj and mul_conj agree.
        let a = C::new(0.3, -0.7);
        let b = C::new(-1.1, 0.4);
        assert_eq!(a.mul_conj(b), a * b.conj());
        assert_eq!(a.abs_sq(), a.mul_conj(a).re);
    }

    #[test]
    fn complex_sqrt_principal() {
        for z in [C::new(-5.0, 10.0), C::new(4.0, 0.0), C::new(0.0, -2.0), C::new(-1.0, 0.0)]
        {
            let s = Field::sqrt(z);
            let sq = s * s;
            assert!(
                (sq.re - z.re).abs() < 1e-9 && (sq.im - z.im).abs() < 1e-9,
                "sqrt({z:?})² = {sq:?}"
            );
            assert!(s.re >= 0.0, "principal branch: {s:?}");
        }
    }

    #[test]
    fn complex_gaussian_unit_second_moment() {
        let mut rng = Rng::seed_from_u64(0);
        let n = 4000;
        let mean_sq: f64 = (0..n)
            .map(|_| C::sample_gaussian(&mut rng).abs_sq())
            .sum::<f64>()
            / n as f64;
        assert!((mean_sq - 1.0).abs() < 0.1, "E|z|² = {mean_sq}");
    }
}
