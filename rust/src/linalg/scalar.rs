//! Scalar abstraction over `f32`/`f64` so linalg and the optimizers are
//! generic in precision (needed by the Fig. C.1 precision ablation).

use std::fmt::Debug;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Real scalar: the float operations the substrate needs, nothing more.
pub trait Scalar:
    Copy
    + Clone
    + Debug
    + PartialOrd
    + PartialEq
    + Default
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
{
    const ZERO: Self;
    const ONE: Self;
    /// Machine epsilon of the type.
    const EPS: Self;

    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    fn sqrt(self) -> Self;
    fn abs(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn max_s(self, other: Self) -> Self;
    fn min_s(self, other: Self) -> Self;
    fn is_finite(self) -> bool;
    /// Truncate the mantissa to bfloat16 precision (keeps f32 exponent).
    /// Identity for f64 inputs converted via f32 path only when requested.
    fn truncate_bf16(self) -> Self;
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPS: Self = f32::EPSILON;

    #[inline]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
    #[inline]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline]
    fn powi(self, n: i32) -> Self {
        f32::powi(self, n)
    }
    #[inline]
    fn max_s(self, other: Self) -> Self {
        f32::max(self, other)
    }
    #[inline]
    fn min_s(self, other: Self) -> Self {
        f32::min(self, other)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }
    #[inline]
    fn truncate_bf16(self) -> Self {
        f32::from_bits(self.to_bits() & 0xFFFF_0000)
    }
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPS: Self = f64::EPSILON;

    #[inline]
    fn from_f64(v: f64) -> Self {
        v
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    #[inline]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline]
    fn powi(self, n: i32) -> Self {
        f64::powi(self, n)
    }
    #[inline]
    fn max_s(self, other: Self) -> Self {
        f64::max(self, other)
    }
    #[inline]
    fn min_s(self, other: Self) -> Self {
        f64::min(self, other)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }
    #[inline]
    fn truncate_bf16(self) -> Self {
        // bf16 truncation is defined through the f32 path; for f64 we go
        // f64 -> f32 -> bf16 -> f64, matching what a bf16 matmul unit sees.
        (f32::from_bits((self as f32).to_bits() & 0xFFFF_0000)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_truncation_drops_low_mantissa() {
        let x: f32 = 1.0 + f32::EPSILON * 100.0;
        let t = x.truncate_bf16();
        assert!(t.to_bits() & 0xFFFF == 0);
        assert!((t - x).abs() < 1e-2);
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(<f32 as Scalar>::from_f64(1.5).to_f64(), 1.5);
        assert_eq!(<f64 as Scalar>::from_f64(1.5).to_f64(), 1.5);
    }
}
