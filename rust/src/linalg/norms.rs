//! Norms and related reductions, generic over the [`Field`] element.

use super::mat::Mat;
use super::matmul::matmul_a_bh;
use super::scalar::{Field, Scalar};

/// Frobenius norm.
pub fn frob_norm<E: Field>(a: &Mat<E>) -> f64 {
    a.norm().to_f64()
}

/// Largest singular value estimate via power iteration on `A Aᴴ`.
///
/// Used to pre-scale Newton–Schulz polar iterations; `iters` in the 10–30
/// range gives plenty of accuracy for a convergence-radius check. On real
/// fields this is the classic `A Aᵀ` power iteration, unchanged.
pub fn spectral_norm_est<E: Field>(a: &Mat<E>, iters: usize) -> f64 {
    let (p, _n) = a.shape();
    if a.is_empty() {
        return 0.0;
    }
    let g = matmul_a_bh(a, a); // p×p gram (Hermitian PSD)
    // Power iteration on the gram matrix.
    let mut v = vec![E::ONE; p];
    let mut lam = 0.0f64;
    for _ in 0..iters {
        // w = G v
        let mut w = vec![E::ZERO; p];
        for i in 0..p {
            let row = g.row(i);
            let mut acc = E::ZERO;
            for j in 0..p {
                acc += row[j] * v[j];
            }
            w[i] = acc;
        }
        let norm = w.iter().map(|x| x.abs_sq().to_f64()).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        lam = norm;
        let inv = E::from_f64(1.0 / norm);
        for (vi, &wi) in v.iter_mut().zip(&w) {
            *vi = wi * inv;
        }
    }
    // lam approximates the top eigenvalue of A Aᴴ = σ_max².
    lam.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CMat;
    use crate::rng::Rng;

    #[test]
    fn frob_of_identity() {
        let i = Mat::<f64>::eye(9);
        assert!((frob_norm(&i) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn spectral_of_diagonal() {
        let mut d = Mat::<f64>::zeros(4, 4);
        d[(0, 0)] = 3.0;
        d[(1, 1)] = -7.0;
        d[(2, 2)] = 1.0;
        d[(3, 3)] = 0.5;
        let s = spectral_norm_est(&d, 50);
        assert!((s - 7.0).abs() < 1e-6, "s={s}");
    }

    #[test]
    fn spectral_bounded_by_frobenius() {
        let mut rng = Rng::seed_from_u64(0);
        let a = Mat::<f64>::randn(20, 35, &mut rng);
        let s = spectral_norm_est(&a, 40);
        assert!(s <= frob_norm(&a) + 1e-9);
        assert!(s > 0.0);
    }

    #[test]
    fn complex_spectral_of_unitary_is_one() {
        let i = CMat::<f64>::eye(4);
        let s = spectral_norm_est(&i, 20);
        assert!((s - 1.0).abs() < 1e-9, "s={s}");
    }
}
