//! Batched dense tensors: one contiguous `(B, p, n)` buffer holding B
//! same-shape matrices, plus batched matmul kernels that parallelize
//! **over the batch dimension**.
//!
//! This is the host-side answer to the paper's Fig. 1 regime: stepping
//! thousands of tiny orthogonal matrices. A 3×3 product never crosses the
//! per-call threshold in [`super::matmul`] (by design — see
//! `worth_parallelizing` there), so a per-matrix loop leaves every worker
//! idle. Here the unit of parallel work is a contiguous *chunk of the
//! batch*: each worker runs the very same serial row-range kernels
//! (`mm_rows` / `at_b_rows` / `a_bt_rows`) once per matrix in its chunk,
//! which makes batched results bit-identical to the single-matrix entry
//! points — the property the batched-vs-loop parity suite pins down.
//!
//! Layout: row-major per matrix, matrices contiguous (matrix `i` occupies
//! `data[i·p·n .. (i+1)·p·n]`), matching the XLA engine's `(B, p, n)`
//! literal layout so batches can cross engines without reshuffling.

use super::mat::Mat;
use super::matmul::{a_bt_rows, at_b_rows, mm_rows};
use super::scalar::Scalar;
use crate::util::pool;

/// B same-shape matrices in one contiguous `(B, p, n)` buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchMat<S: Scalar> {
    b: usize,
    p: usize,
    n: usize,
    data: Vec<S>,
}

impl<S: Scalar> BatchMat<S> {
    /// Zero-filled batch.
    pub fn zeros(b: usize, p: usize, n: usize) -> Self {
        BatchMat { b, p, n, data: vec![S::ZERO; b * p * n] }
    }

    /// Pack a slice of same-shape matrices into one contiguous batch.
    pub fn from_mats(mats: &[Mat<S>]) -> Self {
        if mats.is_empty() {
            return BatchMat::zeros(0, 0, 0);
        }
        let (p, n) = mats[0].shape();
        let mut out = BatchMat::zeros(mats.len(), p, n);
        for (i, m) in mats.iter().enumerate() {
            out.set_mat(i, m);
        }
        out
    }

    /// Copy matrix `m` into batch slot `i` (shapes must match).
    pub fn set_mat(&mut self, i: usize, m: &Mat<S>) {
        assert_eq!(
            m.shape(),
            (self.p, self.n),
            "batch slot {i}: matrix shape mismatch"
        );
        self.mat_mut(i).copy_from_slice(m.as_slice());
    }

    /// Unpack into an existing slice of same-shape matrices.
    pub fn unpack_into(&self, out: &mut [Mat<S>]) {
        assert_eq!(out.len(), self.b, "unpack: {} mats vs batch {}", out.len(), self.b);
        for (i, m) in out.iter_mut().enumerate() {
            assert_eq!(m.shape(), (self.p, self.n), "unpack slot {i}: shape mismatch");
            m.as_mut_slice().copy_from_slice(self.mat(i));
        }
    }

    /// Unpack into freshly-allocated matrices.
    pub fn to_mats(&self) -> Vec<Mat<S>> {
        (0..self.b).map(|i| self.copy_mat(i)).collect()
    }

    /// Copy batch element `i` out as a standalone matrix.
    pub fn copy_mat(&self, i: usize) -> Mat<S> {
        Mat::from_vec(self.p, self.n, self.mat(i).to_vec())
    }

    #[inline]
    pub fn batch(&self) -> usize {
        self.b
    }
    #[inline]
    pub fn rows(&self) -> usize {
        self.p
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.n
    }
    /// `(B, p, n)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.b, self.p, self.n)
    }
    /// Per-matrix `(p, n)`.
    #[inline]
    pub fn mat_shape(&self) -> (usize, usize) {
        (self.p, self.n)
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    #[inline]
    pub fn as_slice(&self) -> &[S] {
        &self.data
    }
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [S] {
        &mut self.data
    }

    /// Borrow batch element `i` as a row-major slice.
    #[inline]
    pub fn mat(&self, i: usize) -> &[S] {
        let stride = self.p * self.n;
        &self.data[i * stride..(i + 1) * stride]
    }

    /// Borrow batch element `i` mutably.
    #[inline]
    pub fn mat_mut(&mut self, i: usize) -> &mut [S] {
        let stride = self.p * self.n;
        &mut self.data[i * stride..(i + 1) * stride]
    }

    /// `self += alpha · other`, elementwise over the whole batch
    /// (batch-sharded across the pool on large buffers: the batched
    /// step's elementwise passes move as much memory as its tiny
    /// matmuls, so leaving them serial would cap multi-core scaling).
    pub fn axpy(&mut self, alpha: S, other: &BatchMat<S>) {
        assert_eq!(self.shape(), other.shape(), "batch shape mismatch in axpy");
        let stride = self.p * self.n;
        let odata = other.data.as_slice();
        elementwise_chunks(&mut self.data, self.b, stride, |range, chunk| {
            let o = &odata[range.start * stride..range.start * stride + chunk.len()];
            for (a, &b) in chunk.iter_mut().zip(o) {
                *a += alpha * b;
            }
        });
    }

    /// `self[i] += alphas[i] · other[i]` — a per-matrix coefficient (the
    /// batched form of POGO's per-matrix λ and Landing's safeguarded η).
    pub fn axpy_per_mat(&mut self, alphas: &[S], other: &BatchMat<S>) {
        assert_eq!(self.shape(), other.shape(), "batch shape mismatch in axpy_per_mat");
        assert_eq!(alphas.len(), self.b, "one alpha per batch element");
        let stride = self.p * self.n;
        let odata = other.data.as_slice();
        elementwise_chunks(&mut self.data, self.b, stride, |range, chunk| {
            for (ci, i) in range.enumerate() {
                let alpha = alphas[i];
                let o = &odata[i * stride..(i + 1) * stride];
                let c = &mut chunk[ci * stride..(ci + 1) * stride];
                for (a, &b) in c.iter_mut().zip(o) {
                    *a += alpha * b;
                }
            }
        });
    }

    /// Scale the whole batch in place (batch-sharded on large buffers).
    pub fn scale_inplace(&mut self, alpha: S) {
        let stride = self.p * self.n;
        elementwise_chunks(&mut self.data, self.b, stride, |_range, chunk| {
            for v in chunk.iter_mut() {
                *v *= alpha;
            }
        });
    }

    /// `self[i] *= alphas[i]` — per-matrix scaling (LandingPC's per-matrix
    /// gradient normalization, VAdam's per-matrix second moment).
    pub fn scale_per_mat(&mut self, alphas: &[S]) {
        assert_eq!(alphas.len(), self.b, "one alpha per batch element");
        let stride = self.p * self.n;
        elementwise_chunks(&mut self.data, self.b, stride, |range, chunk| {
            for (ci, i) in range.enumerate() {
                let alpha = alphas[i];
                for v in chunk[ci * stride..(ci + 1) * stride].iter_mut() {
                    *v *= alpha;
                }
            }
        });
    }

    /// `self − other`, elementwise.
    pub fn sub(&self, other: &BatchMat<S>) -> BatchMat<S> {
        self.zip(other, |a, b| a - b)
    }

    /// Elementwise map into a new batch.
    pub fn map(&self, f: impl Fn(S) -> S) -> BatchMat<S> {
        BatchMat {
            b: self.b,
            p: self.p,
            n: self.n,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Elementwise binary op.
    pub fn zip(&self, other: &BatchMat<S>, f: impl Fn(S, S) -> S) -> BatchMat<S> {
        assert_eq!(self.shape(), other.shape(), "batch shape mismatch in zip");
        BatchMat {
            b: self.b,
            p: self.p,
            n: self.n,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// Subtract the identity from every (square) matrix in the batch.
    pub fn sub_eye_inplace(&mut self) {
        assert_eq!(self.p, self.n, "sub_eye on non-square batch");
        let stride = self.p * self.n;
        for i in 0..self.b {
            for d in 0..self.p {
                self.data[i * stride + d * self.n + d] -= S::ONE;
            }
        }
    }

    /// Per-matrix symmetric part `(Aᵢ + Aᵢᵀ)/2` (square matrices), same
    /// elementwise arithmetic as [`Mat::sym`].
    pub fn sym_per_mat(&self) -> BatchMat<S> {
        assert_eq!(self.p, self.n, "sym on non-square batch");
        let half = S::from_f64(0.5);
        let stride = self.p * self.n;
        let mut out = BatchMat::zeros(self.b, self.p, self.n);
        for i in 0..self.b {
            let src = &self.data[i * stride..(i + 1) * stride];
            let dst = &mut out.data[i * stride..(i + 1) * stride];
            for r in 0..self.p {
                for c in 0..self.n {
                    dst[r * self.n + c] = (src[r * self.n + c] + src[c * self.n + r]) * half;
                }
            }
        }
        out
    }

    /// Per-matrix squared Frobenius norm, accumulated in the same order as
    /// [`Mat::norm_sq`] (sequential over each matrix) so per-matrix and
    /// batched optimizer state stay bit-identical.
    pub fn norm_sq_per_mat(&self) -> Vec<S> {
        let stride = self.p * self.n;
        (0..self.b)
            .map(|i| {
                let mut acc = S::ZERO;
                for &v in &self.data[i * stride..(i + 1) * stride] {
                    acc += v * v;
                }
                acc
            })
            .collect()
    }

    /// Max |entry| over the whole batch.
    pub fn max_abs(&self) -> S {
        let mut m = S::ZERO;
        for &v in &self.data {
            m = m.max_s(v.abs());
        }
        m
    }

    /// True if every entry is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

/// Minimum buffer length (scalars) before an elementwise batch op shards
/// across the pool. `pool::parallel_rows` spawns fresh scoped threads on
/// every call (there is no persistent pool), and an elementwise pass is
/// pure memory traffic (1 flop per element), so the spawn only pays off
/// on multi-megabyte buffers — at the Fig. 1 shape this is B ≈ 29k of
/// 3×3 matrices.
const ELEMWISE_PAR_ELEMS: usize = 1 << 18;

/// Run `f(batch_range, chunk)` over the buffer, sharding contiguous
/// whole-matrix chunks across the pool when the buffer is large enough
/// (per-element arithmetic is order-independent here, so sharding never
/// changes results). Serial fallback covers small buffers and the
/// degenerate `stride == 0` case.
fn elementwise_chunks<S: Scalar, F>(data: &mut [S], b: usize, stride: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [S]) + Sync,
{
    if data.len() < ELEMWISE_PAR_ELEMS || b <= 1 || stride == 0 {
        f(0..b, data);
    } else {
        pool::parallel_rows(data, b, stride, f);
    }
}

/// Minimum total flops before a batched matmul shards the batch across
/// workers. Lower than the single-matmul threshold (`matmul::PAR_FLOPS`,
/// 2²²) because one call covers B independent kernels with zero
/// coordination between them — but only moderately lower: the spawn
/// itself is NOT amortized across calls (`pool::parallel_rows` uses
/// `std::thread::scope`, fresh OS threads every time), so the sharded
/// work still has to dwarf thread setup even on few-core machines. At
/// the Fig. 1 shape (3×3, 54 flops each) the pool engages from
/// B ≈ 19.4k upward; smaller batches win on packing alone.
const BATCH_PAR_FLOPS: usize = 1 << 20;

/// Whether a batched call of `total_flops` work (summed over the batch)
/// should shard batch chunks across the pool.
#[inline]
fn batch_worth_parallelizing(total_flops: usize) -> bool {
    total_flops >= BATCH_PAR_FLOPS
}

/// Run `kernel(i, out_chunk_for_matrix_i)` for every batch element,
/// sharding contiguous batch chunks across the pool when the total work
/// justifies it.
fn for_each_mat<S: Scalar, F>(out: &mut BatchMat<S>, total_flops: usize, kernel: F)
where
    F: Fn(usize, &mut [S]) + Sync,
{
    let (b, p, n) = out.shape();
    let stride = p * n;
    if !batch_worth_parallelizing(total_flops) {
        for i in 0..b {
            kernel(i, out.mat_mut(i));
        }
    } else {
        // Treat the batch buffer as `b` rows of `p·n` scalars: parallel_rows
        // hands each worker a contiguous run of whole matrices.
        pool::parallel_rows(out.as_mut_slice(), b, stride, |range, chunk| {
            for (ci, i) in range.enumerate() {
                kernel(i, &mut chunk[ci * stride..(ci + 1) * stride]);
            }
        });
    }
}

/// `C[i] = A[i] · B[i]` for every batch element. A: `(B, m, k)`,
/// B: `(B, k, n)`, C: `(B, m, n)`.
pub fn batch_matmul_into<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>, c: &mut BatchMat<S>) {
    let (ba, m, k) = a.shape();
    let (bb, k2, n) = b.shape();
    assert_eq!(ba, bb, "batch_matmul batch mismatch: {ba} vs {bb}");
    assert_eq!(k, k2, "batch_matmul inner dim mismatch: {k} vs {k2}");
    assert_eq!(c.shape(), (ba, m, n), "batch_matmul output shape mismatch");
    c.as_mut_slice().fill(S::ZERO);
    for_each_mat(c, 2 * ba * m * n * k, |i, out| {
        mm_rows(a.mat(i), b.mat(i), 0..m, out, k, n);
    });
}

/// `C[i] = A[i] · B[i]`, allocating the output.
pub fn batch_matmul<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>) -> BatchMat<S> {
    let mut c = BatchMat::zeros(a.batch(), a.rows(), b.cols());
    batch_matmul_into(a, b, &mut c);
    c
}

/// `C[i] = A[i]ᵀ · B[i]`. A: `(B, k, m)`, B: `(B, k, n)`, C: `(B, m, n)`.
pub fn batch_at_b_into<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>, c: &mut BatchMat<S>) {
    let (ba, k, m) = a.shape();
    let (bb, k2, n) = b.shape();
    assert_eq!(ba, bb, "batch_at_b batch mismatch: {ba} vs {bb}");
    assert_eq!(k, k2, "batch_at_b inner dim mismatch: {k} vs {k2}");
    assert_eq!(c.shape(), (ba, m, n), "batch_at_b output shape mismatch");
    c.as_mut_slice().fill(S::ZERO);
    for_each_mat(c, 2 * ba * m * n * k, |i, out| {
        at_b_rows(a.mat(i), b.mat(i), 0..m, out, k, m, n);
    });
}

/// `C[i] = A[i]ᵀ · B[i]`, allocating the output.
pub fn batch_at_b<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>) -> BatchMat<S> {
    let mut c = BatchMat::zeros(a.batch(), a.cols(), b.cols());
    batch_at_b_into(a, b, &mut c);
    c
}

/// `C[i] = A[i] · B[i]ᵀ`. A: `(B, m, k)`, B: `(B, n, k)`, C: `(B, m, n)`.
pub fn batch_a_bt_into<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>, c: &mut BatchMat<S>) {
    let (ba, m, k) = a.shape();
    let (bb, n, k2) = b.shape();
    assert_eq!(ba, bb, "batch_a_bt batch mismatch: {ba} vs {bb}");
    assert_eq!(k, k2, "batch_a_bt inner dim mismatch: {k} vs {k2}");
    assert_eq!(c.shape(), (ba, m, n), "batch_a_bt output shape mismatch");
    for_each_mat(c, 2 * ba * m * n * k, |i, out| {
        a_bt_rows(a.mat(i), b.mat(i), 0..m, out, k, n);
    });
}

/// `C[i] = A[i] · B[i]ᵀ`, allocating the output.
pub fn batch_a_bt<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>) -> BatchMat<S> {
    let mut c = BatchMat::zeros(a.batch(), a.rows(), b.rows());
    batch_a_bt_into(a, b, &mut c);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_a_bt, matmul_at_b};
    use crate::rng::Rng;

    type M = Mat<f64>;

    fn random_batch(b: usize, p: usize, n: usize, rng: &mut Rng) -> (Vec<M>, BatchMat<f64>) {
        let mats: Vec<M> = (0..b).map(|_| M::randn(p, n, rng)).collect();
        let batch = BatchMat::from_mats(&mats);
        (mats, batch)
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::seed_from_u64(0);
        let (mats, batch) = random_batch(5, 3, 7, &mut rng);
        assert_eq!(batch.shape(), (5, 3, 7));
        let back = batch.to_mats();
        assert_eq!(mats, back);
        // mat(i) views the right contiguous window.
        for (i, m) in mats.iter().enumerate() {
            assert_eq!(batch.mat(i), m.as_slice());
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let batch = BatchMat::<f32>::from_mats(&[]);
        assert_eq!(batch.batch(), 0);
        assert!(batch.is_empty());
        assert!(batch.to_mats().is_empty());
    }

    #[test]
    fn batch_matmul_matches_per_matrix() {
        let mut rng = Rng::seed_from_u64(1);
        let (am, ab) = random_batch(6, 4, 5, &mut rng);
        let (bm, bb) = random_batch(6, 5, 3, &mut rng);
        let c = batch_matmul(&ab, &bb);
        for i in 0..6 {
            let want = matmul(&am[i], &bm[i]);
            assert!(c.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn batch_at_b_matches_per_matrix() {
        let mut rng = Rng::seed_from_u64(2);
        let (am, ab) = random_batch(4, 7, 4, &mut rng);
        let (bm, bb) = random_batch(4, 7, 6, &mut rng);
        let c = batch_at_b(&ab, &bb);
        assert_eq!(c.shape(), (4, 4, 6));
        for i in 0..4 {
            let want = matmul_at_b(&am[i], &bm[i]);
            assert!(c.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn batch_a_bt_matches_per_matrix() {
        let mut rng = Rng::seed_from_u64(3);
        let (am, ab) = random_batch(4, 3, 8, &mut rng);
        let (bm, bb) = random_batch(4, 5, 8, &mut rng);
        let c = batch_a_bt(&ab, &bb);
        assert_eq!(c.shape(), (4, 3, 5));
        for i in 0..4 {
            let want = matmul_a_bt(&am[i], &bm[i]);
            assert!(c.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn large_batch_parallel_path_matches_serial() {
        // Big enough that for_each_mat shards across the pool.
        let mut rng = Rng::seed_from_u64(4);
        let (am, ab) = random_batch(512, 16, 16, &mut rng);
        let (bm, bb) = random_batch(512, 16, 16, &mut rng);
        assert!(batch_worth_parallelizing(2 * 512 * 16 * 16 * 16));
        let c = batch_matmul(&ab, &bb);
        for i in [0, 17, 255, 511] {
            let want = matmul(&am[i], &bm[i]);
            assert!(c.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn large_elementwise_parallel_path_matches_serial() {
        // Buffer past ELEMWISE_PAR_ELEMS so axpy/scale shard across the
        // pool; results must equal the per-matrix reference exactly.
        let b = 300;
        let (p, n) = (32, 32);
        assert!(b * p * n >= ELEMWISE_PAR_ELEMS);
        let mut rng = Rng::seed_from_u64(8);
        let (xm, mut xb) = random_batch(b, p, n, &mut rng);
        let (om, ob) = random_batch(b, p, n, &mut rng);
        let alphas: Vec<f64> = (0..b).map(|i| (i % 5) as f64 - 2.0).collect();
        xb.axpy(0.25, &ob);
        xb.axpy_per_mat(&alphas, &ob);
        xb.scale_inplace(3.0);
        for i in [0, 149, 299] {
            let mut want = xm[i].clone();
            want.axpy(0.25, &om[i]);
            want.axpy(alphas[i], &om[i]);
            want.scale_inplace(3.0);
            assert!(xb.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn per_mat_scalar_ops() {
        let mut rng = Rng::seed_from_u64(5);
        let (mats, mut batch) = random_batch(3, 2, 4, &mut rng);
        let (other_m, other) = random_batch(3, 2, 4, &mut rng);
        let alphas = [2.0, -1.0, 0.5];
        batch.axpy_per_mat(&alphas, &other);
        for i in 0..3 {
            let mut want = mats[i].clone();
            want.axpy(alphas[i], &other_m[i]);
            assert!(batch.copy_mat(i).sub(&want).max_abs() == 0.0);
        }
        batch.scale_per_mat(&[1.0, 0.0, 2.0]);
        assert!(batch.copy_mat(1).max_abs() == 0.0);
    }

    #[test]
    fn sub_eye_and_sym_match_mat_ops() {
        let mut rng = Rng::seed_from_u64(6);
        let (mats, mut batch) = random_batch(4, 5, 5, &mut rng);
        let sym = batch.sym_per_mat();
        batch.sub_eye_inplace();
        for i in 0..4 {
            let mut want = mats[i].clone();
            want.sub_eye_inplace();
            assert!(batch.copy_mat(i).sub(&want).max_abs() == 0.0);
            assert!(sym.copy_mat(i).sub(&mats[i].sym()).max_abs() == 0.0);
        }
    }

    #[test]
    fn norm_sq_per_mat_matches_mat_norm_sq() {
        let mut rng = Rng::seed_from_u64(7);
        let (mats, batch) = random_batch(5, 6, 3, &mut rng);
        let ns = batch.norm_sq_per_mat();
        for i in 0..5 {
            assert_eq!(ns[i], mats[i].norm_sq(), "batch {i}");
        }
    }
}
