//! Batched dense tensors: one contiguous `(B, p, n)` buffer holding B
//! same-shape matrices, plus batched matmul kernels that parallelize
//! **over the batch dimension** — generic over the [`Field`] element, so
//! the same engine serves real Stiefel groups and complex unitary groups
//! (the Born-machine MPS regime of Fig. 8).
//!
//! This is the host-side answer to the paper's Fig. 1 regime: stepping
//! thousands of tiny orthogonal matrices. A 3×3 product never crosses the
//! per-call threshold in [`super::matmul`] (by design — see
//! `worth_parallelizing` there), so a per-matrix loop leaves every worker
//! idle. Here the unit of parallel work is a contiguous *chunk of the
//! batch*: each worker runs the very same serial row-range kernels
//! (`mm_rows` / `ah_b_rows` / `a_bh_rows`, dispatched through the
//! runtime-selected [`StepKernel`](crate::linalg::StepKernel)) once per
//! matrix in its chunk, which makes batched results bit-identical to the
//! single-matrix entry points — the property the batched-vs-loop parity
//! suite pins down.
//!
//! [`for_each_mat_fused`] is the driver for the fused single-pass step
//! (`StepKernel::pogo_step` / `landing_step`): same batch-chunk sharding,
//! but each worker owns a mutable window of the iterate tensor *plus* the
//! matching window of a per-matrix `f64` output (λ / safeguarded η).
//!
//! Layout: row-major per matrix, matrices contiguous (matrix `i` occupies
//! `data[i·p·n .. (i+1)·p·n]`), matching the XLA engine's `(B, p, n)`
//! literal layout so batches can cross engines without reshuffling.

use super::mat::Mat;
use super::scalar::{Field, Scalar};
use crate::util::pool;

/// B same-shape matrices in one contiguous `(B, p, n)` buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchMat<E: Field> {
    b: usize,
    p: usize,
    n: usize,
    data: Vec<E>,
}

impl<E: Field> BatchMat<E> {
    /// Zero-filled batch.
    pub fn zeros(b: usize, p: usize, n: usize) -> Self {
        BatchMat { b, p, n, data: vec![E::ZERO; b * p * n] }
    }

    /// Pack a slice of same-shape matrices into one contiguous batch.
    pub fn from_mats(mats: &[Mat<E>]) -> Self {
        if mats.is_empty() {
            return BatchMat::zeros(0, 0, 0);
        }
        let (p, n) = mats[0].shape();
        let mut out = BatchMat::zeros(mats.len(), p, n);
        for (i, m) in mats.iter().enumerate() {
            out.set_mat(i, m);
        }
        out
    }

    /// Copy matrix `m` into batch slot `i` (shapes must match).
    pub fn set_mat(&mut self, i: usize, m: &Mat<E>) {
        assert_eq!(
            m.shape(),
            (self.p, self.n),
            "batch slot {i}: matrix shape mismatch"
        );
        self.mat_mut(i).copy_from_slice(m.as_slice());
    }

    /// Unpack into an existing slice of same-shape matrices.
    pub fn unpack_into(&self, out: &mut [Mat<E>]) {
        assert_eq!(out.len(), self.b, "unpack: {} mats vs batch {}", out.len(), self.b);
        for (i, m) in out.iter_mut().enumerate() {
            assert_eq!(m.shape(), (self.p, self.n), "unpack slot {i}: shape mismatch");
            m.as_mut_slice().copy_from_slice(self.mat(i));
        }
    }

    /// Unpack into freshly-allocated matrices.
    pub fn to_mats(&self) -> Vec<Mat<E>> {
        (0..self.b).map(|i| self.copy_mat(i)).collect()
    }

    /// Copy batch element `i` out as a standalone matrix.
    pub fn copy_mat(&self, i: usize) -> Mat<E> {
        Mat::from_vec(self.p, self.n, self.mat(i).to_vec())
    }

    #[inline]
    pub fn batch(&self) -> usize {
        self.b
    }
    #[inline]
    pub fn rows(&self) -> usize {
        self.p
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.n
    }
    /// `(B, p, n)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.b, self.p, self.n)
    }
    /// Per-matrix `(p, n)`.
    #[inline]
    pub fn mat_shape(&self) -> (usize, usize) {
        (self.p, self.n)
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    #[inline]
    pub fn as_slice(&self) -> &[E] {
        &self.data
    }
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [E] {
        &mut self.data
    }

    /// Borrow batch element `i` as a row-major slice.
    #[inline]
    pub fn mat(&self, i: usize) -> &[E] {
        let stride = self.p * self.n;
        &self.data[i * stride..(i + 1) * stride]
    }

    /// Borrow batch element `i` mutably.
    #[inline]
    pub fn mat_mut(&mut self, i: usize) -> &mut [E] {
        let stride = self.p * self.n;
        &mut self.data[i * stride..(i + 1) * stride]
    }

    /// `self += alpha · other`, elementwise over the whole batch
    /// (batch-sharded across the pool on large buffers: the batched
    /// step's elementwise passes move as much memory as its tiny
    /// matmuls, so leaving them serial would cap multi-core scaling).
    pub fn axpy(&mut self, alpha: E, other: &BatchMat<E>) {
        assert_eq!(self.shape(), other.shape(), "batch shape mismatch in axpy");
        let stride = self.p * self.n;
        let odata = other.data.as_slice();
        elementwise_chunks(&mut self.data, self.b, stride, |range, chunk| {
            let o = &odata[range.start * stride..range.start * stride + chunk.len()];
            for (a, &b) in chunk.iter_mut().zip(o) {
                *a += alpha * b;
            }
        });
    }

    /// `self[i] += alphas[i] · other[i]` — a per-matrix coefficient (the
    /// batched form of POGO's per-matrix λ and Landing's safeguarded η).
    pub fn axpy_per_mat(&mut self, alphas: &[E], other: &BatchMat<E>) {
        assert_eq!(self.shape(), other.shape(), "batch shape mismatch in axpy_per_mat");
        assert_eq!(alphas.len(), self.b, "one alpha per batch element");
        let stride = self.p * self.n;
        let odata = other.data.as_slice();
        elementwise_chunks(&mut self.data, self.b, stride, |range, chunk| {
            for (ci, i) in range.enumerate() {
                let alpha = alphas[i];
                let o = &odata[i * stride..(i + 1) * stride];
                let c = &mut chunk[ci * stride..(ci + 1) * stride];
                for (a, &b) in c.iter_mut().zip(o) {
                    *a += alpha * b;
                }
            }
        });
    }

    /// Scale the whole batch in place (batch-sharded on large buffers).
    pub fn scale_inplace(&mut self, alpha: E) {
        let stride = self.p * self.n;
        elementwise_chunks(&mut self.data, self.b, stride, |_range, chunk| {
            for v in chunk.iter_mut() {
                *v *= alpha;
            }
        });
    }

    /// `self[i] *= alphas[i]` — per-matrix scaling (LandingPC's per-matrix
    /// gradient normalization, VAdam's per-matrix second moment).
    pub fn scale_per_mat(&mut self, alphas: &[E]) {
        assert_eq!(alphas.len(), self.b, "one alpha per batch element");
        let stride = self.p * self.n;
        elementwise_chunks(&mut self.data, self.b, stride, |range, chunk| {
            for (ci, i) in range.enumerate() {
                let alpha = alphas[i];
                for v in chunk[ci * stride..(ci + 1) * stride].iter_mut() {
                    *v *= alpha;
                }
            }
        });
    }

    /// `self − other`, elementwise.
    pub fn sub(&self, other: &BatchMat<E>) -> BatchMat<E> {
        self.zip(other, |a, b| a - b)
    }

    /// Elementwise map into a new batch.
    pub fn map(&self, f: impl Fn(E) -> E) -> BatchMat<E> {
        BatchMat {
            b: self.b,
            p: self.p,
            n: self.n,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Elementwise binary op.
    pub fn zip(&self, other: &BatchMat<E>, f: impl Fn(E, E) -> E) -> BatchMat<E> {
        assert_eq!(self.shape(), other.shape(), "batch shape mismatch in zip");
        BatchMat {
            b: self.b,
            p: self.p,
            n: self.n,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// Elementwise binary op in place: `self[i] = f(&mut self[i], other[i])`
    /// (batch-sharded on large buffers, like [`BatchMat::axpy`]). The
    /// allocation-free sibling of [`BatchMat::zip`] for optimizer state
    /// updates that used to build a temporary batch.
    pub fn zip_inplace(&mut self, other: &BatchMat<E>, f: impl Fn(&mut E, E) + Sync) {
        assert_eq!(self.shape(), other.shape(), "batch shape mismatch in zip_inplace");
        let stride = self.p * self.n;
        let odata = other.data.as_slice();
        elementwise_chunks(&mut self.data, self.b, stride, |range, chunk| {
            let o = &odata[range.start * stride..range.start * stride + chunk.len()];
            for (a, &b) in chunk.iter_mut().zip(o) {
                f(a, b);
            }
        });
    }

    /// Elementwise binary op into a reusable output buffer:
    /// `out[i] = f(self[i], other[i])` (batch-sharded on large buffers).
    /// `out` must already have this batch's shape — callers size it once
    /// and reuse it every step.
    pub fn zip_into(&self, other: &BatchMat<E>, out: &mut BatchMat<E>, f: impl Fn(E, E) -> E + Sync) {
        assert_eq!(self.shape(), other.shape(), "batch shape mismatch in zip_into");
        assert_eq!(self.shape(), out.shape(), "output shape mismatch in zip_into");
        let stride = self.p * self.n;
        let adata = self.data.as_slice();
        let bdata = other.data.as_slice();
        elementwise_chunks(&mut out.data, out.b, stride, |range, chunk| {
            let lo = range.start * stride;
            let a = &adata[lo..lo + chunk.len()];
            let b = &bdata[lo..lo + chunk.len()];
            for ((o, &x), &y) in chunk.iter_mut().zip(a).zip(b) {
                *o = f(x, y);
            }
        });
    }

    /// Subtract the identity from every (square) matrix in the batch.
    pub fn sub_eye_inplace(&mut self) {
        assert_eq!(self.p, self.n, "sub_eye on non-square batch");
        let stride = self.p * self.n;
        for i in 0..self.b {
            for d in 0..self.p {
                self.data[i * stride + d * self.n + d] -= E::ONE;
            }
        }
    }

    /// Per-matrix Hermitian-symmetric part `(Aᵢ + Aᵢᴴ)/2` (square
    /// matrices), same elementwise arithmetic as [`Mat::sym_h`] — and
    /// bit-identical to the old real-only `sym` on real fields.
    pub fn sym_per_mat(&self) -> BatchMat<E> {
        assert_eq!(self.p, self.n, "sym on non-square batch");
        let half = E::from_f64(0.5);
        let stride = self.p * self.n;
        let mut out = BatchMat::zeros(self.b, self.p, self.n);
        for i in 0..self.b {
            let src = &self.data[i * stride..(i + 1) * stride];
            let dst = &mut out.data[i * stride..(i + 1) * stride];
            for r in 0..self.p {
                for c in 0..self.n {
                    dst[r * self.n + c] =
                        (src[r * self.n + c] + src[c * self.n + r].conj()) * half;
                }
            }
        }
        out
    }

    /// Per-matrix squared Frobenius norm (`Σ |a_ij|²`, always real),
    /// accumulated in the same order as [`Mat::norm_sq`] (sequential over
    /// each matrix) so per-matrix and batched optimizer state stay
    /// bit-identical.
    pub fn norm_sq_per_mat(&self) -> Vec<E::Real> {
        let mut out = Vec::new();
        self.norm_sq_per_mat_into(&mut out);
        out
    }

    /// [`BatchMat::norm_sq_per_mat`] into a reusable buffer (cleared and
    /// refilled; same per-matrix sequential accumulation, so results are
    /// bit-identical). Steady-state callers hold the buffer across steps
    /// and never re-allocate.
    pub fn norm_sq_per_mat_into(&self, out: &mut Vec<E::Real>) {
        let stride = self.p * self.n;
        out.clear();
        out.extend((0..self.b).map(|i| {
            let mut acc = E::Real::ZERO;
            for &v in &self.data[i * stride..(i + 1) * stride] {
                acc += v.abs_sq();
            }
            acc
        }));
    }

    /// True if every entry is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

/// Real-only extras (ordered scalars).
impl<S: Scalar> BatchMat<S> {
    /// Max |entry| over the whole batch.
    pub fn max_abs(&self) -> S {
        let mut m = S::ZERO;
        for &v in &self.data {
            m = m.max_s(v.abs());
        }
        m
    }
}

/// Minimum buffer length (scalars) before an elementwise batch op shards
/// across the pool. An elementwise pass is pure memory traffic (1 flop
/// per element), so even the resident pool's wake/barrier round-trip
/// (µs-scale, vs ms-scale thread spawn under `POGO_POOL=spawn`) only pays
/// off on multi-megabyte buffers — at the Fig. 1 shape this is B ≈ 29k of
/// 3×3 matrices. The threshold predates the resident pool and is kept
/// as-is: sharding geometry is part of the bit-exactness contract, and
/// below it the caller thread is faster anyway.
const ELEMWISE_PAR_ELEMS: usize = 1 << 18;

/// Run `f(batch_range, chunk)` over the buffer, sharding contiguous
/// whole-matrix chunks across the pool when the buffer is large enough
/// (per-element arithmetic is order-independent here, so sharding never
/// changes results). Serial fallback covers small buffers and the
/// degenerate `stride == 0` case.
fn elementwise_chunks<E: Field, F>(data: &mut [E], b: usize, stride: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [E]) + Sync,
{
    if data.len() < ELEMWISE_PAR_ELEMS || b <= 1 || stride == 0 {
        f(0..b, data);
    } else {
        pool::parallel_rows(data, b, stride, f);
    }
}

/// Minimum total flops before a batched matmul shards the batch across
/// workers. Lower than the single-matmul threshold (`matmul::PAR_FLOPS`,
/// 2²²) because one call covers B independent kernels with zero
/// coordination between them — but only moderately lower: dispatch is a
/// condvar wake + barrier on the resident pool (and a full thread spawn
/// under `POGO_POOL=spawn`), so the sharded work still has to dwarf that
/// round-trip even on few-core machines. At the Fig. 1 shape (3×3,
/// 54 flops each) the pool engages from B ≈ 19.4k upward; smaller batches
/// win on packing alone. The value is unchanged from the spawn era — the
/// shard geometry it gates is part of the bit-exactness contract.
const BATCH_PAR_FLOPS: usize = 1 << 20;

/// Whether a batched call of `total_flops` work (summed over the batch)
/// should shard batch chunks across the pool.
#[inline]
fn batch_worth_parallelizing(total_flops: usize) -> bool {
    total_flops >= BATCH_PAR_FLOPS
}

/// Run `kernel(i, out_chunk_for_matrix_i)` for every batch element,
/// sharding contiguous batch chunks across the pool when the total work
/// justifies it.
fn for_each_mat<E: Field, F>(out: &mut BatchMat<E>, total_flops: usize, kernel: F)
where
    F: Fn(usize, &mut [E]) + Sync,
{
    let (b, p, n) = out.shape();
    let stride = p * n;
    if !batch_worth_parallelizing(total_flops) {
        for i in 0..b {
            kernel(i, out.mat_mut(i));
        }
    } else {
        // Treat the batch buffer as `b` rows of `p·n` scalars: parallel_rows
        // hands each worker a contiguous run of whole matrices.
        pool::parallel_rows(out.as_mut_slice(), b, stride, |range, chunk| {
            for (ci, i) in range.enumerate() {
                kernel(i, &mut chunk[ci * stride..(ci + 1) * stride]);
            }
        });
    }
}

/// Flop estimate for one fused POGO/Landing step over a `(B, p, n)`
/// batch: ~6 matrix products of ~2·p²·n flops each per element (two
/// grams, two relative-gradient products, the normal/correction product,
/// and the elementwise passes folded in as product-equivalents), so
/// `12·B·p²·n`. Used only for the parallelization decision — the
/// threshold logic never needs exact counts.
#[inline]
pub fn fused_step_flops(b: usize, p: usize, n: usize) -> usize {
    12 * b * p * p * n
}

/// Minimum total flops before a fused step shards the batch across
/// workers. The 5-pass world pays one pool dispatch *per kernel pass*
/// (`BATCH_PAR_FLOPS` gates each of them separately); the fused step pays
/// ONE dispatch for the whole update, so the wake/barrier round-trip
/// amortizes over ~6× more arithmetic and the same absolute floor
/// (2²⁰ flops per dispatch) engages at ~6× smaller batches. At the
/// Fig. 1 shape (3×3, 324 fused flops per element) the pool engages from
/// B ≈ 3.2k upward; a single 3×3 step (B = 1) can never cross the floor.
const FUSED_PAR_FLOPS: usize = 1 << 20;

/// Whether a fused batched step of `total_flops` work (see
/// [`fused_step_flops`]) should shard batch chunks across the pool.
#[inline]
pub fn fused_worth_parallelizing(total_flops: usize) -> bool {
    total_flops >= FUSED_PAR_FLOPS
}

/// Driver for the fused single-pass step: runs
/// `f(batch_range, x_chunk, lam_chunk)` over matching windows of the
/// iterate tensor `x` (stride `p·n`) and the per-matrix `f64` output
/// `lams` (stride 1, one slot per batch element — POGO's λ or Landing's
/// safeguarded η), sharding contiguous whole-matrix chunks across the
/// pool when `total_flops` crosses [`fused_worth_parallelizing`].
///
/// The closure must process its chunk strictly per-matrix (matrix `ci` of
/// the chunk is `x_chunk[ci·p·n .. (ci+1)·p·n]`, its output slot
/// `lam_chunk[ci]`), which keeps sharded and serial execution
/// bit-identical.
pub fn for_each_mat_fused<E: Field, F>(
    x: &mut BatchMat<E>,
    lams: &mut [f64],
    total_flops: usize,
    f: F,
) where
    F: Fn(std::ops::Range<usize>, &mut [E], &mut [f64]) + Sync,
{
    let (b, p, n) = x.shape();
    assert_eq!(lams.len(), b, "one lambda slot per batch element");
    let stride = p * n;
    if !fused_worth_parallelizing(total_flops) || b <= 1 || stride == 0 {
        f(0..b, x.as_mut_slice(), lams);
    } else {
        pool::parallel_rows_pair(x.as_mut_slice(), lams, b, stride, 1, f);
    }
}

/// `C[i] = A[i] · B[i]` for every batch element. A: `(B, m, k)`,
/// B: `(B, k, n)`, C: `(B, m, n)`.
pub fn batch_matmul_into<E: Field>(a: &BatchMat<E>, b: &BatchMat<E>, c: &mut BatchMat<E>) {
    let (ba, m, k) = a.shape();
    let (bb, k2, n) = b.shape();
    assert_eq!(ba, bb, "batch_matmul batch mismatch: {ba} vs {bb}");
    assert_eq!(k, k2, "batch_matmul inner dim mismatch: {k} vs {k2}");
    assert_eq!(c.shape(), (ba, m, n), "batch_matmul output shape mismatch");
    c.as_mut_slice().fill(E::ZERO);
    let kern = E::step_kernel();
    for_each_mat(c, 2 * ba * m * n * k, |i, out| {
        kern.mm_rows(a.mat(i), b.mat(i), 0..m, out, k, n);
    });
}

/// `C[i] = A[i] · B[i]`, allocating the output.
pub fn batch_matmul<E: Field>(a: &BatchMat<E>, b: &BatchMat<E>) -> BatchMat<E> {
    let mut c = BatchMat::zeros(a.batch(), a.rows(), b.cols());
    batch_matmul_into(a, b, &mut c);
    c
}

/// `C[i] = A[i]ᴴ · B[i]`. A: `(B, k, m)`, B: `(B, k, n)`, C: `(B, m, n)`.
/// Real fields: the batched `Aᵀ·B`.
pub fn batch_ah_b_into<E: Field>(a: &BatchMat<E>, b: &BatchMat<E>, c: &mut BatchMat<E>) {
    let (ba, k, m) = a.shape();
    let (bb, k2, n) = b.shape();
    assert_eq!(ba, bb, "batch_ah_b batch mismatch: {ba} vs {bb}");
    assert_eq!(k, k2, "batch_ah_b inner dim mismatch: {k} vs {k2}");
    assert_eq!(c.shape(), (ba, m, n), "batch_ah_b output shape mismatch");
    c.as_mut_slice().fill(E::ZERO);
    let kern = E::step_kernel();
    for_each_mat(c, 2 * ba * m * n * k, |i, out| {
        kern.ah_b_rows(a.mat(i), b.mat(i), 0..m, out, k, m, n);
    });
}

/// `C[i] = A[i]ᴴ · B[i]`, allocating the output.
pub fn batch_ah_b<E: Field>(a: &BatchMat<E>, b: &BatchMat<E>) -> BatchMat<E> {
    let mut c = BatchMat::zeros(a.batch(), a.cols(), b.cols());
    batch_ah_b_into(a, b, &mut c);
    c
}

/// `C[i] = A[i] · B[i]ᴴ`. A: `(B, m, k)`, B: `(B, n, k)`, C: `(B, m, n)`.
/// Real fields: the batched `A·Bᵀ`.
pub fn batch_a_bh_into<E: Field>(a: &BatchMat<E>, b: &BatchMat<E>, c: &mut BatchMat<E>) {
    let (ba, m, k) = a.shape();
    let (bb, n, k2) = b.shape();
    assert_eq!(ba, bb, "batch_a_bh batch mismatch: {ba} vs {bb}");
    assert_eq!(k, k2, "batch_a_bh inner dim mismatch: {k} vs {k2}");
    assert_eq!(c.shape(), (ba, m, n), "batch_a_bh output shape mismatch");
    let kern = E::step_kernel();
    for_each_mat(c, 2 * ba * m * n * k, |i, out| {
        kern.a_bh_rows(a.mat(i), b.mat(i), 0..m, out, k, n);
    });
}

/// `C[i] = A[i] · B[i]ᴴ`, allocating the output.
pub fn batch_a_bh<E: Field>(a: &BatchMat<E>, b: &BatchMat<E>) -> BatchMat<E> {
    let mut c = BatchMat::zeros(a.batch(), a.rows(), b.rows());
    batch_a_bh_into(a, b, &mut c);
    c
}

/// Real-field aliases (transpose = adjoint on ordered scalars).
pub fn batch_at_b<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>) -> BatchMat<S> {
    batch_ah_b(a, b)
}

pub fn batch_at_b_into<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>, c: &mut BatchMat<S>) {
    batch_ah_b_into(a, b, c)
}

pub fn batch_a_bt<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>) -> BatchMat<S> {
    batch_a_bh(a, b)
}

pub fn batch_a_bt_into<S: Scalar>(a: &BatchMat<S>, b: &BatchMat<S>, c: &mut BatchMat<S>) {
    batch_a_bh_into(a, b, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_a_bh, matmul_a_bt, matmul_ah_b, matmul_at_b, Complex};
    use crate::rng::Rng;

    type M = Mat<f64>;

    fn random_batch(b: usize, p: usize, n: usize, rng: &mut Rng) -> (Vec<M>, BatchMat<f64>) {
        let mats: Vec<M> = (0..b).map(|_| M::randn(p, n, rng)).collect();
        let batch = BatchMat::from_mats(&mats);
        (mats, batch)
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::seed_from_u64(0);
        let (mats, batch) = random_batch(5, 3, 7, &mut rng);
        assert_eq!(batch.shape(), (5, 3, 7));
        let back = batch.to_mats();
        assert_eq!(mats, back);
        // mat(i) views the right contiguous window.
        for (i, m) in mats.iter().enumerate() {
            assert_eq!(batch.mat(i), m.as_slice());
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let batch = BatchMat::<f32>::from_mats(&[]);
        assert_eq!(batch.batch(), 0);
        assert!(batch.is_empty());
        assert!(batch.to_mats().is_empty());
    }

    #[test]
    fn batch_matmul_matches_per_matrix() {
        let mut rng = Rng::seed_from_u64(1);
        let (am, ab) = random_batch(6, 4, 5, &mut rng);
        let (bm, bb) = random_batch(6, 5, 3, &mut rng);
        let c = batch_matmul(&ab, &bb);
        for i in 0..6 {
            let want = matmul(&am[i], &bm[i]);
            assert!(c.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn batch_at_b_matches_per_matrix() {
        let mut rng = Rng::seed_from_u64(2);
        let (am, ab) = random_batch(4, 7, 4, &mut rng);
        let (bm, bb) = random_batch(4, 7, 6, &mut rng);
        let c = batch_at_b(&ab, &bb);
        assert_eq!(c.shape(), (4, 4, 6));
        for i in 0..4 {
            let want = matmul_at_b(&am[i], &bm[i]);
            assert!(c.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn batch_a_bt_matches_per_matrix() {
        let mut rng = Rng::seed_from_u64(3);
        let (am, ab) = random_batch(4, 3, 8, &mut rng);
        let (bm, bb) = random_batch(4, 5, 8, &mut rng);
        let c = batch_a_bt(&ab, &bb);
        assert_eq!(c.shape(), (4, 3, 5));
        for i in 0..4 {
            let want = matmul_a_bt(&am[i], &bm[i]);
            assert!(c.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn complex_batch_kernels_match_per_matrix() {
        // The batched complex kernels must agree with the single-matrix
        // complex entry points exactly (they run the same row-range code).
        type CM = Mat<Complex<f64>>;
        let mut rng = Rng::seed_from_u64(9);
        let am: Vec<CM> = (0..5).map(|_| CM::randn(4, 6, &mut rng)).collect();
        let bm: Vec<CM> = (0..5).map(|_| CM::randn(3, 6, &mut rng)).collect();
        let ab = BatchMat::from_mats(&am);
        let bb = BatchMat::from_mats(&bm);
        let c = batch_a_bh(&ab, &bb);
        assert_eq!(c.shape(), (5, 4, 3));
        for i in 0..5 {
            let want = matmul_a_bh(&am[i], &bm[i]);
            assert!(c.copy_mat(i).sub(&want).norm().to_f64() == 0.0, "batch {i}");
        }
        let cm: Vec<CM> = (0..5).map(|_| CM::randn(4, 6, &mut rng)).collect();
        let cb = BatchMat::from_mats(&cm);
        let d = batch_ah_b(&ab, &cb);
        assert_eq!(d.shape(), (5, 6, 6));
        for i in 0..5 {
            let want = matmul_ah_b(&am[i], &cm[i]);
            assert!(d.copy_mat(i).sub(&want).norm().to_f64() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn large_batch_parallel_path_matches_serial() {
        // Big enough that for_each_mat shards across the pool.
        let mut rng = Rng::seed_from_u64(4);
        let (am, ab) = random_batch(512, 16, 16, &mut rng);
        let (bm, bb) = random_batch(512, 16, 16, &mut rng);
        assert!(batch_worth_parallelizing(2 * 512 * 16 * 16 * 16));
        let c = batch_matmul(&ab, &bb);
        for i in [0, 17, 255, 511] {
            let want = matmul(&am[i], &bm[i]);
            assert!(c.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn large_elementwise_parallel_path_matches_serial() {
        // Buffer past ELEMWISE_PAR_ELEMS so axpy/scale shard across the
        // pool; results must equal the per-matrix reference exactly.
        let b = 300;
        let (p, n) = (32, 32);
        assert!(b * p * n >= ELEMWISE_PAR_ELEMS);
        let mut rng = Rng::seed_from_u64(8);
        let (xm, mut xb) = random_batch(b, p, n, &mut rng);
        let (om, ob) = random_batch(b, p, n, &mut rng);
        let alphas: Vec<f64> = (0..b).map(|i| (i % 5) as f64 - 2.0).collect();
        xb.axpy(0.25, &ob);
        xb.axpy_per_mat(&alphas, &ob);
        xb.scale_inplace(3.0);
        for i in [0, 149, 299] {
            let mut want = xm[i].clone();
            want.axpy(0.25, &om[i]);
            want.axpy(alphas[i], &om[i]);
            want.scale_inplace(3.0);
            assert!(xb.copy_mat(i).sub(&want).max_abs() == 0.0, "batch {i}");
        }
    }

    #[test]
    fn per_mat_scalar_ops() {
        let mut rng = Rng::seed_from_u64(5);
        let (mats, mut batch) = random_batch(3, 2, 4, &mut rng);
        let (other_m, other) = random_batch(3, 2, 4, &mut rng);
        let alphas = [2.0, -1.0, 0.5];
        batch.axpy_per_mat(&alphas, &other);
        for i in 0..3 {
            let mut want = mats[i].clone();
            want.axpy(alphas[i], &other_m[i]);
            assert!(batch.copy_mat(i).sub(&want).max_abs() == 0.0);
        }
        batch.scale_per_mat(&[1.0, 0.0, 2.0]);
        assert!(batch.copy_mat(1).max_abs() == 0.0);
    }

    #[test]
    fn sub_eye_and_sym_match_mat_ops() {
        let mut rng = Rng::seed_from_u64(6);
        let (mats, mut batch) = random_batch(4, 5, 5, &mut rng);
        let sym = batch.sym_per_mat();
        batch.sub_eye_inplace();
        for i in 0..4 {
            let mut want = mats[i].clone();
            want.sub_eye_inplace();
            assert!(batch.copy_mat(i).sub(&want).max_abs() == 0.0);
            assert!(sym.copy_mat(i).sub(&mats[i].sym()).max_abs() == 0.0);
        }
    }

    #[test]
    fn fused_threshold_keeps_small_steps_serial() {
        // Regression for the fused-kernel re-derivation: a single 3×3
        // fused step (324 flops) must never spawn threads — nor must the
        // whole Fig. 1 B = 1024 batch of them; the pool engages only from
        // B ≈ 3.2k upward at that shape.
        assert!(!fused_worth_parallelizing(fused_step_flops(1, 3, 3)));
        assert!(!fused_worth_parallelizing(fused_step_flops(1024, 3, 3)));
        assert!(fused_worth_parallelizing(fused_step_flops(4096, 3, 3)));
        // The floor itself: one spawn per 2²⁰ fused flops.
        assert!(fused_worth_parallelizing(1 << 20));
        assert!(!fused_worth_parallelizing((1 << 20) - 1));
        // Flop model sanity: 12·B·p²·n.
        assert_eq!(fused_step_flops(2, 3, 5), 12 * 2 * 9 * 5);
    }

    #[test]
    fn for_each_mat_fused_covers_serial_and_parallel() {
        // Drive the fused driver with a recognizable per-matrix stamp on
        // both sides of the threshold; sharding must not change results.
        for (b, p, n) in [(7usize, 3usize, 3usize), (4096, 3, 3)] {
            let mut x = BatchMat::<f64>::zeros(b, p, n);
            let mut lams = vec![0.0f64; b];
            let stride = p * n;
            for_each_mat_fused(
                &mut x,
                &mut lams,
                fused_step_flops(b, p, n),
                |range, xc, lc| {
                    for (ci, i) in range.enumerate() {
                        for (j, v) in xc[ci * stride..(ci + 1) * stride].iter_mut().enumerate() {
                            *v = (i * stride + j) as f64;
                        }
                        lc[ci] = i as f64 + 0.5;
                    }
                },
            );
            for (j, &v) in x.as_slice().iter().enumerate() {
                assert_eq!(v, j as f64, "B={b}");
            }
            for (i, &l) in lams.iter().enumerate() {
                assert_eq!(l, i as f64 + 0.5, "B={b}");
            }
        }
    }

    #[test]
    fn norm_sq_per_mat_matches_mat_norm_sq() {
        let mut rng = Rng::seed_from_u64(7);
        let (mats, batch) = random_batch(5, 6, 3, &mut rng);
        let ns = batch.norm_sq_per_mat();
        for i in 0..5 {
            assert_eq!(ns[i], mats[i].norm_sq(), "batch {i}");
        }
    }
}
