//! Row-major dense matrix, generic over a [`Field`] element.
//!
//! One `Mat<E>` type serves both manifolds: `Mat<f32>` / `Mat<f64>` are
//! the real Stiefel workhorses, `Mat<Complex<S>>` (aliased `CMat<S>`) the
//! complex ones. Field-generic operations live in the `impl<E: Field>`
//! block; operations that only make sense over an ordered real scalar
//! (`skew`, `max_abs`, casts, bf16 truncation) stay in the
//! `impl<S: Scalar>` block, so real call sites compile to exactly the
//! pre-`Field` code.

use super::scalar::{Field, Scalar};
use crate::rng::Rng;
use std::fmt;
use std::ops::{Index, IndexMut};

/// A dense `rows × cols` matrix stored row-major in a `Vec`.
///
/// This is the workhorse type of the whole reproduction: optimizer states,
/// gradients, datasets and PJRT literals all view into `Mat` buffers.
#[derive(Clone, PartialEq)]
pub struct Mat<E: Field> {
    rows: usize,
    cols: usize,
    data: Vec<E>,
}

impl<E: Field> Mat<E> {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![E::ZERO; rows * cols] }
    }

    /// Matrix of ones.
    pub fn ones(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![E::ONE; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = E::ONE;
        }
        m
    }

    /// Build from a row-major vector (takes ownership; length must match).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<E>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape {rows}x{cols} vs len {}", data.len());
        Mat { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> E) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// i.i.d. standard Gaussian entries (for complex fields, re/im each
    /// `N(0, ½)` so that `E|z|² = 1`).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut data = vec![E::ZERO; rows * cols];
        for v in data.iter_mut() {
            *v = E::sample_gaussian(rng);
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    #[inline]
    pub fn as_slice(&self) -> &[E] {
        &self.data
    }
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [E] {
        &mut self.data
    }
    /// Consume into the underlying row-major buffer.
    pub fn into_vec(self) -> Vec<E> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[E] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [E] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// The one blocked transposition kernel (cache friendliness on big
    /// matrices), parameterized by an elementwise map so `transpose` and
    /// `adjoint` cannot drift apart.
    fn transpose_with(&self, f: impl Fn(E) -> E) -> Mat<E> {
        let mut out = Mat::zeros(self.cols, self.rows);
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = f(self.data[i * self.cols + j]);
                    }
                }
            }
        }
        out
    }

    /// Transposed copy (no conjugation; see [`Mat::adjoint`]).
    pub fn transpose(&self) -> Mat<E> {
        self.transpose_with(|v| v)
    }

    /// Conjugate transpose `Aᴴ` — identical to [`Mat::transpose`] on real
    /// fields; the generic update rules are written against this.
    pub fn adjoint(&self) -> Mat<E> {
        self.transpose_with(|v| v.conj())
    }

    /// Elementwise conjugate (identity on real fields).
    pub fn conj(&self) -> Mat<E> {
        self.map(|v| v.conj())
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(E) -> E) -> Mat<E> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(E) -> E) {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat<E>) -> Mat<E> {
        self.zip(other, |a, b| a + b)
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat<E>) -> Mat<E> {
        self.zip(other, |a, b| a - b)
    }

    /// Elementwise binary op.
    pub fn zip(&self, other: &Mat<E>, f: impl Fn(E, E) -> E) -> Mat<E> {
        assert_eq!(self.shape(), other.shape(), "shape mismatch in zip");
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// `self += alpha * other` (axpy).
    pub fn axpy(&mut self, alpha: E, other: &Mat<E>) {
        assert_eq!(self.shape(), other.shape(), "shape mismatch in axpy");
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale in place.
    pub fn scale_inplace(&mut self, alpha: E) {
        for v in self.data.iter_mut() {
            *v *= alpha;
        }
    }

    /// Scaled copy.
    pub fn scale(&self, alpha: E) -> Mat<E> {
        self.map(|v| v * alpha)
    }

    /// Real part of the Frobenius inner product `Re Tr(Bᴴ A)` — for real
    /// fields this is [`Mat::dot`] exactly (same accumulation order).
    pub fn dot_re(&self, other: &Mat<E>) -> E::Real {
        assert_eq!(self.shape(), other.shape(), "shape mismatch in dot_re");
        let mut acc = E::Real::ZERO;
        for (&a, &b) in self.data.iter().zip(&other.data) {
            acc += a.mul_conj(b).re();
        }
        acc
    }

    /// Squared Frobenius norm `Σ |a_ij|²` (always real).
    pub fn norm_sq(&self) -> E::Real {
        let mut acc = E::Real::ZERO;
        for &v in &self.data {
            acc += v.abs_sq();
        }
        acc
    }

    /// Frobenius norm.
    pub fn norm(&self) -> E::Real {
        Field::sqrt(self.norm_sq())
    }

    /// Trace (square matrices).
    pub fn trace(&self) -> E {
        assert_eq!(self.rows, self.cols, "trace of non-square matrix");
        let mut t = E::ZERO;
        for i in 0..self.rows {
            t += self.data[i * self.cols + i];
        }
        t
    }

    /// Skew-Hermitian part `(A − Aᴴ)/2` (square matrices) — on real
    /// fields this is the skew-symmetric part, bit-identical to
    /// [`Mat::skew`].
    pub fn skew_h(&self) -> Mat<E> {
        assert_eq!(self.rows, self.cols, "skew_h of non-square matrix");
        let half = E::from_f64(0.5);
        Mat::from_fn(self.rows, self.cols, |i, j| {
            (self.data[i * self.cols + j] - self.data[j * self.cols + i].conj()) * half
        })
    }

    /// Hermitian-symmetric part `(A + Aᴴ)/2` (square matrices) — the real
    /// instantiation is [`Mat::sym`] bit-for-bit.
    pub fn sym_h(&self) -> Mat<E> {
        assert_eq!(self.rows, self.cols, "sym_h of non-square matrix");
        let half = E::from_f64(0.5);
        Mat::from_fn(self.rows, self.cols, |i, j| {
            (self.data[i * self.cols + j] + self.data[j * self.cols + i].conj()) * half
        })
    }

    /// Subtract identity in place (square matrices): `A -= I`.
    pub fn sub_eye_inplace(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] -= E::ONE;
        }
    }

    /// Add `alpha` to the diagonal in place.
    pub fn add_diag_inplace(&mut self, alpha: E) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha;
        }
    }

    /// Column `j` as a new vector.
    pub fn col(&self, j: usize) -> Vec<E> {
        (0..self.rows).map(|i| self.data[i * self.cols + j]).collect()
    }

    /// Copy a sub-block `rows × cols` starting at (r0, c0).
    pub fn block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Mat<E> {
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols, "block out of range");
        Mat::from_fn(rows, cols, |i, j| self.data[(r0 + i) * self.cols + (c0 + j)])
    }

    /// Write a block into this matrix at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Mat<E>) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols, "block out of range");
        for i in 0..b.rows {
            for j in 0..b.cols {
                self.data[(r0 + i) * self.cols + (c0 + j)] = b.data[i * b.cols + j];
            }
        }
    }

    /// True if all entries are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

/// Real-only operations: ordering, casts, the uniform sampler, bf16.
impl<S: Scalar> Mat<S> {
    /// i.i.d. uniform entries in [lo, hi).
    pub fn rand_uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Rng) -> Self {
        let mut data = vec![S::ZERO; rows * cols];
        for v in data.iter_mut() {
            *v = S::from_f64(rng.uniform_in(lo, hi));
        }
        Mat { rows, cols, data }
    }

    /// Skew-symmetric part `(A − Aᵀ)/2` (square matrices).
    pub fn skew(&self) -> Mat<S> {
        assert_eq!(self.rows, self.cols, "skew of non-square matrix");
        let half = S::from_f64(0.5);
        Mat::from_fn(self.rows, self.cols, |i, j| {
            (self.data[i * self.cols + j] - self.data[j * self.cols + i]) * half
        })
    }

    /// Symmetric part `(A + Aᵀ)/2` (square matrices).
    pub fn sym(&self) -> Mat<S> {
        assert_eq!(self.rows, self.cols, "sym of non-square matrix");
        let half = S::from_f64(0.5);
        Mat::from_fn(self.rows, self.cols, |i, j| {
            (self.data[i * self.cols + j] + self.data[j * self.cols + i]) * half
        })
    }

    /// Frobenius inner product `Tr(otherᵀ self)`.
    pub fn dot(&self, other: &Mat<S>) -> S {
        assert_eq!(self.shape(), other.shape(), "shape mismatch in dot");
        let mut acc = S::ZERO;
        for (&a, &b) in self.data.iter().zip(&other.data) {
            acc += a * b;
        }
        acc
    }

    /// Cast into another scalar type (f32 <-> f64), via f64.
    pub fn cast<T: Scalar>(&self) -> Mat<T> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| T::from_f64(v.to_f64())).collect(),
        }
    }

    /// Max |entry|, useful in tests.
    pub fn max_abs(&self) -> S {
        let mut m = S::ZERO;
        for &v in &self.data {
            m = m.max_s(v.abs());
        }
        m
    }

    /// Truncate every entry's mantissa to bfloat16 precision (Fig. C.1).
    pub fn truncate_bf16(&self) -> Mat<S> {
        self.map(|v| v.truncate_bf16())
    }
}

impl<E: Field> Index<(usize, usize)> for Mat<E> {
    type Output = E;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &E {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl<E: Field> IndexMut<(usize, usize)> for Mat<E> {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut E {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl<E: Field> fmt::Debug for Mat<E> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                let v = self[(i, j)];
                if E::COMPLEX {
                    write!(
                        f,
                        "{:>9.3}{:+.3}i ",
                        v.re().to_f64(),
                        v.im().to_f64()
                    )?;
                } else {
                    write!(f, "{:>10.4} ", v.re().to_f64())?;
                }
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    type M = Mat<f64>;

    #[test]
    fn construction_and_indexing() {
        let m = M::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn eye_trace() {
        assert_eq!(M::eye(4).trace(), 4.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from_u64(0);
        let m = M::randn(7, 13, &mut rng);
        let t2 = m.transpose().transpose();
        assert_eq!(m, t2);
    }

    #[test]
    fn adjoint_equals_transpose_on_reals() {
        let mut rng = Rng::seed_from_u64(4);
        let m = M::randn(5, 9, &mut rng);
        assert_eq!(m.adjoint(), m.transpose());
    }

    #[test]
    fn skew_plus_sym_is_identity_decomposition() {
        let mut rng = Rng::seed_from_u64(1);
        let a = M::randn(5, 5, &mut rng);
        let rec = a.skew().add(&a.sym());
        assert!(rec.sub(&a).max_abs() < 1e-12);
    }

    #[test]
    fn skew_is_antisymmetric() {
        let mut rng = Rng::seed_from_u64(2);
        let s = M::randn(6, 6, &mut rng).skew();
        assert!(s.add(&s.transpose()).max_abs() < 1e-12);
    }

    #[test]
    fn hermitian_ops_match_real_ops_on_reals() {
        let mut rng = Rng::seed_from_u64(5);
        let a = M::randn(6, 6, &mut rng);
        assert_eq!(a.skew_h(), a.skew());
        assert_eq!(a.sym_h(), a.sym());
        assert_eq!(a.dot_re(&a), a.dot(&a));
    }

    #[test]
    fn axpy_and_norm() {
        let a = M::ones(3, 3);
        let mut b = M::zeros(3, 3);
        b.axpy(2.0, &a);
        assert_eq!(b.norm_sq(), 36.0);
    }

    #[test]
    fn block_ops() {
        let m = M::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let b = m.block(1, 2, 2, 2);
        assert_eq!(b[(0, 0)], 6.0);
        assert_eq!(b[(1, 1)], 11.0);
        let mut z = M::zeros(4, 4);
        z.set_block(2, 2, &b);
        assert_eq!(z[(3, 3)], 11.0);
    }

    #[test]
    fn cast_roundtrip() {
        let mut rng = Rng::seed_from_u64(3);
        let m = Mat::<f32>::randn(3, 3, &mut rng);
        let d: Mat<f64> = m.cast();
        let back: Mat<f32> = d.cast();
        assert_eq!(m, back);
    }

    #[test]
    #[should_panic]
    fn mismatched_add_panics() {
        let _ = M::zeros(2, 2).add(&M::zeros(2, 3));
    }
}
