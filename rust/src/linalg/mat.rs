//! Row-major dense matrix.

use super::scalar::Scalar;
use crate::rng::Rng;
use std::fmt;
use std::ops::{Index, IndexMut};

/// A dense `rows × cols` matrix stored row-major in a `Vec`.
///
/// This is the workhorse type of the whole reproduction: optimizer states,
/// gradients, datasets and PJRT literals all view into `Mat` buffers.
#[derive(Clone, PartialEq)]
pub struct Mat<S: Scalar> {
    rows: usize,
    cols: usize,
    data: Vec<S>,
}

impl<S: Scalar> Mat<S> {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![S::ZERO; rows * cols] }
    }

    /// Matrix of ones.
    pub fn ones(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![S::ONE; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = S::ONE;
        }
        m
    }

    /// Build from a row-major vector (takes ownership; length must match).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<S>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape {rows}x{cols} vs len {}", data.len());
        Mat { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> S) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// i.i.d. standard Gaussian entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut data = vec![S::ZERO; rows * cols];
        for v in data.iter_mut() {
            *v = S::from_f64(rng.gaussian());
        }
        Mat { rows, cols, data }
    }

    /// i.i.d. uniform entries in [lo, hi).
    pub fn rand_uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Rng) -> Self {
        let mut data = vec![S::ZERO; rows * cols];
        for v in data.iter_mut() {
            *v = S::from_f64(rng.uniform_in(lo, hi));
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    #[inline]
    pub fn as_slice(&self) -> &[S] {
        &self.data
    }
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [S] {
        &mut self.data
    }
    /// Consume into the underlying row-major buffer.
    pub fn into_vec(self) -> Vec<S> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[S] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [S] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat<S> {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(S) -> S) -> Mat<S> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(S) -> S) {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat<S>) -> Mat<S> {
        self.zip(other, |a, b| a + b)
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat<S>) -> Mat<S> {
        self.zip(other, |a, b| a - b)
    }

    /// Elementwise binary op.
    pub fn zip(&self, other: &Mat<S>, f: impl Fn(S, S) -> S) -> Mat<S> {
        assert_eq!(self.shape(), other.shape(), "shape mismatch in zip");
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// `self += alpha * other` (axpy).
    pub fn axpy(&mut self, alpha: S, other: &Mat<S>) {
        assert_eq!(self.shape(), other.shape(), "shape mismatch in axpy");
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale in place.
    pub fn scale_inplace(&mut self, alpha: S) {
        for v in self.data.iter_mut() {
            *v *= alpha;
        }
    }

    /// Scaled copy.
    pub fn scale(&self, alpha: S) -> Mat<S> {
        self.map(|v| v * alpha)
    }

    /// Frobenius inner product `Tr(otherᵀ self)`.
    pub fn dot(&self, other: &Mat<S>) -> S {
        assert_eq!(self.shape(), other.shape(), "shape mismatch in dot");
        let mut acc = S::ZERO;
        for (&a, &b) in self.data.iter().zip(&other.data) {
            acc += a * b;
        }
        acc
    }

    /// Squared Frobenius norm.
    pub fn norm_sq(&self) -> S {
        self.dot(self)
    }

    /// Frobenius norm.
    pub fn norm(&self) -> S {
        self.norm_sq().sqrt()
    }

    /// Trace (square matrices).
    pub fn trace(&self) -> S {
        assert_eq!(self.rows, self.cols, "trace of non-square matrix");
        let mut t = S::ZERO;
        for i in 0..self.rows {
            t += self.data[i * self.cols + i];
        }
        t
    }

    /// Skew-symmetric part `(A − Aᵀ)/2` (square matrices).
    pub fn skew(&self) -> Mat<S> {
        assert_eq!(self.rows, self.cols, "skew of non-square matrix");
        let half = S::from_f64(0.5);
        Mat::from_fn(self.rows, self.cols, |i, j| {
            (self.data[i * self.cols + j] - self.data[j * self.cols + i]) * half
        })
    }

    /// Symmetric part `(A + Aᵀ)/2` (square matrices).
    pub fn sym(&self) -> Mat<S> {
        assert_eq!(self.rows, self.cols, "sym of non-square matrix");
        let half = S::from_f64(0.5);
        Mat::from_fn(self.rows, self.cols, |i, j| {
            (self.data[i * self.cols + j] + self.data[j * self.cols + i]) * half
        })
    }

    /// Subtract identity in place (square matrices): `A -= I`.
    pub fn sub_eye_inplace(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] -= S::ONE;
        }
    }

    /// Add `alpha` to the diagonal in place.
    pub fn add_diag_inplace(&mut self, alpha: S) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha;
        }
    }

    /// Column `j` as a new vector.
    pub fn col(&self, j: usize) -> Vec<S> {
        (0..self.rows).map(|i| self.data[i * self.cols + j]).collect()
    }

    /// Copy a sub-block `rows × cols` starting at (r0, c0).
    pub fn block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Mat<S> {
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols, "block out of range");
        Mat::from_fn(rows, cols, |i, j| self.data[(r0 + i) * self.cols + (c0 + j)])
    }

    /// Write a block into this matrix at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Mat<S>) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols, "block out of range");
        for i in 0..b.rows {
            for j in 0..b.cols {
                self.data[(r0 + i) * self.cols + (c0 + j)] = b.data[i * b.cols + j];
            }
        }
    }

    /// Cast into another scalar type (f32 <-> f64), via f64.
    pub fn cast<T: Scalar>(&self) -> Mat<T> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| T::from_f64(v.to_f64())).collect(),
        }
    }

    /// Max |entry|, useful in tests.
    pub fn max_abs(&self) -> S {
        let mut m = S::ZERO;
        for &v in &self.data {
            m = m.max_s(v.abs());
        }
        m
    }

    /// True if all entries are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    /// Truncate every entry's mantissa to bfloat16 precision (Fig. C.1).
    pub fn truncate_bf16(&self) -> Mat<S> {
        self.map(|v| v.truncate_bf16())
    }
}

impl<S: Scalar> Index<(usize, usize)> for Mat<S> {
    type Output = S;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &S {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl<S: Scalar> IndexMut<(usize, usize)> for Mat<S> {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut S {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl<S: Scalar> fmt::Debug for Mat<S> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self[(i, j)].to_f64())?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    type M = Mat<f64>;

    #[test]
    fn construction_and_indexing() {
        let m = M::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn eye_trace() {
        assert_eq!(M::eye(4).trace(), 4.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from_u64(0);
        let m = M::randn(7, 13, &mut rng);
        let t2 = m.transpose().transpose();
        assert_eq!(m, t2);
    }

    #[test]
    fn skew_plus_sym_is_identity_decomposition() {
        let mut rng = Rng::seed_from_u64(1);
        let a = M::randn(5, 5, &mut rng);
        let rec = a.skew().add(&a.sym());
        assert!(rec.sub(&a).max_abs() < 1e-12);
    }

    #[test]
    fn skew_is_antisymmetric() {
        let mut rng = Rng::seed_from_u64(2);
        let s = M::randn(6, 6, &mut rng).skew();
        assert!(s.add(&s.transpose()).max_abs() < 1e-12);
    }

    #[test]
    fn axpy_and_norm() {
        let a = M::ones(3, 3);
        let mut b = M::zeros(3, 3);
        b.axpy(2.0, &a);
        assert_eq!(b.norm_sq(), 36.0);
    }

    #[test]
    fn block_ops() {
        let m = M::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let b = m.block(1, 2, 2, 2);
        assert_eq!(b[(0, 0)], 6.0);
        assert_eq!(b[(1, 1)], 11.0);
        let mut z = M::zeros(4, 4);
        z.set_block(2, 2, &b);
        assert_eq!(z[(3, 3)], 11.0);
    }

    #[test]
    fn cast_roundtrip() {
        let mut rng = Rng::seed_from_u64(3);
        let m = Mat::<f32>::randn(3, 3, &mut rng);
        let d: Mat<f64> = m.cast();
        let back: Mat<f32> = d.cast();
        assert_eq!(m, back);
    }

    #[test]
    #[should_panic]
    fn mismatched_add_panics() {
        let _ = M::zeros(2, 2).add(&M::zeros(2, 3));
    }
}
