//! Householder QR and the QR retraction.
//!
//! This is the substrate the *retraction-based baselines* (RGD, RSDM) stand
//! on. It is intentionally a host-side sequential algorithm — reproducing
//! the paper's central systems point that QR-class retractions do not map
//! onto accelerator matmul units, unlike POGO's five matrix products.

use super::mat::Mat;
use super::scalar::Scalar;

/// Thin QR of a tall matrix `A (m × k, m ≥ k)`: returns column-orthonormal
/// `Q (m × k)` with `R` diag forced positive (canonical/retraction form).
///
/// Householder reflections, applied in-place; `O(2mk² − 2k³/3)` flops.
pub fn qr_thin<S: Scalar>(a: &Mat<S>) -> Mat<S> {
    let (m, k) = a.shape();
    assert!(m >= k, "qr_thin expects a tall matrix, got {m}x{k}");
    // Work on a copy; store Householder vectors in the lower triangle.
    let mut r = a.clone();
    // v_j held separately (full length m) for clarity.
    let mut vs: Vec<Vec<S>> = Vec::with_capacity(k);
    let mut diag_sign: Vec<S> = Vec::with_capacity(k);

    for j in 0..k {
        // Compute the Householder vector for column j, rows j..m.
        let mut norm_sq = S::ZERO;
        for i in j..m {
            let x = r[(i, j)];
            norm_sq += x * x;
        }
        let norm = norm_sq.sqrt();
        let x0 = r[(j, j)];
        let alpha = if x0 >= S::ZERO { -norm } else { norm };
        let mut v = vec![S::ZERO; m];
        for i in j..m {
            v[i] = r[(i, j)];
        }
        v[j] -= alpha;
        let vnorm_sq: S = v[j..].iter().map(|&x| x * x).sum();
        if vnorm_sq.to_f64() > 0.0 {
            // Apply H = I − 2 v vᵀ / (vᵀv) to R[j.., j..].
            for c in j..k {
                let mut dot = S::ZERO;
                for i in j..m {
                    dot += v[i] * r[(i, c)];
                }
                let coef = S::from_f64(2.0) * dot / vnorm_sq;
                for i in j..m {
                    let upd = coef * v[i];
                    r[(i, c)] -= upd;
                }
            }
        }
        vs.push(v);
        // Track the sign of R's diagonal so we can canonicalize Q.
        let d = r[(j, j)];
        diag_sign.push(if d >= S::ZERO { S::ONE } else { -S::ONE });
    }

    // Accumulate Q = H_0 H_1 … H_{k−1} applied to the first k columns of I.
    let mut q = Mat::zeros(m, k);
    for j in 0..k {
        q[(j, j)] = S::ONE;
    }
    for jj in (0..k).rev() {
        let v = &vs[jj];
        let vnorm_sq: S = v[jj..].iter().map(|&x| x * x).sum();
        if vnorm_sq.to_f64() == 0.0 {
            continue;
        }
        for c in 0..k {
            let mut dot = S::ZERO;
            for i in jj..m {
                dot += v[i] * q[(i, c)];
            }
            let coef = S::from_f64(2.0) * dot / vnorm_sq;
            for i in jj..m {
                let upd = coef * v[i];
                q[(i, c)] -= upd;
            }
        }
    }
    // Canonical form: flip columns so R's diagonal is positive.
    for (j, s) in diag_sign.iter().enumerate() {
        if *s < S::ZERO {
            for i in 0..m {
                let neg = -q[(i, j)];
                q[(i, j)] = neg;
            }
        }
    }
    q
}

/// QR *retraction* for wide row-orthogonal matrices: given `X (p × n)`
/// (p ≤ n, rows ~orthonormal), return the row-orthonormal matrix obtained
/// by thin-QR of `Xᵀ` and transposing back.
pub fn qr_retract_rows<S: Scalar>(x: &Mat<S>) -> Mat<S> {
    let (p, n) = x.shape();
    assert!(p <= n, "expected a wide matrix, got {p}x{n}");
    qr_thin(&x.transpose()).transpose()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_a_bt, matmul_at_b};
    use crate::rng::Rng;

    #[test]
    fn q_is_column_orthonormal() {
        let mut rng = Rng::seed_from_u64(0);
        for &(m, k) in &[(5, 5), (10, 4), (33, 17)] {
            let a = Mat::<f64>::randn(m, k, &mut rng);
            let q = qr_thin(&a);
            let mut qtq = matmul_at_b(&q, &q);
            qtq.sub_eye_inplace();
            assert!(qtq.max_abs() < 1e-10, "({m},{k}): err={}", qtq.max_abs());
        }
    }

    #[test]
    fn q_spans_a() {
        // A = Q R  =>  Q Qᵀ A = A for full column rank A.
        let mut rng = Rng::seed_from_u64(1);
        let a = Mat::<f64>::randn(12, 5, &mut rng);
        let q = qr_thin(&a);
        // R = Qᵀ A; reconstruct QR and compare.
        let r = matmul_at_b(&q, &a);
        let rec = crate::linalg::matmul(&q, &r);
        assert!(rec.sub(&a).max_abs() < 1e-10);
    }

    #[test]
    fn r_diag_positive_canonical() {
        let mut rng = Rng::seed_from_u64(2);
        let a = Mat::<f64>::randn(9, 6, &mut rng);
        let q = qr_thin(&a);
        let r = matmul_at_b(&q, &a);
        for j in 0..6 {
            assert!(r[(j, j)] > 0.0, "R[{j},{j}]={}", r[(j, j)]);
        }
    }

    #[test]
    fn retraction_lands_on_stiefel() {
        let mut rng = Rng::seed_from_u64(3);
        let x = Mat::<f64>::randn(7, 19, &mut rng);
        let y = qr_retract_rows(&x);
        let mut g = matmul_a_bt(&y, &y);
        g.sub_eye_inplace();
        assert!(g.max_abs() < 1e-10);
    }

    #[test]
    fn retraction_fixes_points_on_manifold() {
        // A row-orthonormal X should be (nearly) a fixed point.
        let mut rng = Rng::seed_from_u64(4);
        let x = qr_retract_rows(&Mat::<f64>::randn(4, 9, &mut rng));
        let y = qr_retract_rows(&x);
        assert!(y.sub(&x).max_abs() < 1e-9);
    }
}
