//! Cache-blocked, multi-threaded matrix multiplication, generic over a
//! [`Field`] element.
//!
//! The single entry point is the adjoint-parameterized
//! [`gemm`]`(opa, opb, a, b)` computing `C = op(A)·op(B)` with
//! `op ∈ {`[`Op::N`]`, `[`Op::H`]`}`, so no explicit transposes (or
//! conjugations) are materialized on the hot path. The historical named
//! entry points (`matmul`, `matmul_ah_b`, `matmul_a_bh`, the `_at_b` /
//! `_a_bt` real aliases, and their `_into` twins) survive as thin
//! `#[inline]` wrappers over `gemm` so call sites migrate at leisure:
//!
//! - `matmul(A, B)      = gemm(N, N, ..) = A · B`
//! - `matmul_ah_b(A, B) = gemm(H, N, ..) = Aᴴ · B`   (relative gradient `Xᴴ G`)
//! - `matmul_a_bh(A, B) = gemm(N, H, ..) = A · Bᴴ`   (gram `M Mᴴ`, normal step)
//!
//! On real fields conjugation is the identity, so `matmul_at_b` /
//! `matmul_a_bt` remain as the familiar real-named aliases and compile to
//! exactly the pre-`Field` kernels. A complex product through the same
//! kernels performs 4 real multiplies per element pair in place of the old
//! split-plane `CMat` scheme's 4 real matmuls — same flops, one pass.
//!
//! `gemm` routes its row kernels through the runtime-selected
//! [`StepKernel`](crate::linalg::StepKernel) (`E::step_kernel()`), so a
//! single-matrix product picks up the same AVX2/NEON microkernels as the
//! fused batched step — and, by the kernel contract, the same bits.
//!
//! The kernel is an i-k-j loop with an axpy inner loop, which LLVM
//! auto-vectorizes to the native SIMD width at `opt-level=3`; k is blocked
//! for L1/L2 residency and rows are sharded over `std::thread::scope`
//! workers above a flop threshold. This is deliberately not a BLAS — the
//! XLA engine is the "accelerated" path of the paper; this substrate just
//! has to be fast enough that the retraction baselines' QR cost, not the
//! matmul, dominates (as it does in the paper on GPU).

use super::mat::Mat;
use super::scalar::{Field, Scalar};
use crate::util::pool;

/// k-block size: keep a (KB)-long stripe of B rows hot in cache. Shared
/// with the arch microkernels in `linalg::simd` so blocking (and thus
/// summation order) is identical across kernels.
pub(crate) const KB: usize = 256;
/// Minimum flops before we bother spawning threads.
const PAR_FLOPS: usize = 1 << 22;

/// Whether a single matmul call is worth sharding across worker threads.
///
/// The decision is derived from **this call's own total work** (`2·m·n·k`
/// flops) and nothing else — never from surrounding batch context. A
/// `(B, p, n)` group of small matrices (the paper's Fig. 1 regime:
/// thousands of 3×3 kernels) must parallelize **over the batch dimension**
/// in [`crate::linalg::batch`], one worker per contiguous batch chunk;
/// spawning inside each tiny product would pay thread-setup costs that
/// dwarf the 54-flop 3×3 arithmetic itself. Keeping the threshold
/// per-call therefore guarantees the small-matrix path stays strictly
/// serial while the batched engine owns the B-parallelism.
#[inline]
pub(crate) fn worth_parallelizing(flops: usize) -> bool {
    flops >= PAR_FLOPS
}

/// Serial row-range kernel for `C = A·B` (A: m×k, B: k×n), writing rows
/// `rows` of C into `c_chunk` (which must already be zeroed). Shared by
/// [`matmul_into`] and the batched engine in [`crate::linalg::batch`],
/// which invokes it once per batch element so batched and single-matrix
/// results are bit-identical.
pub(crate) fn mm_rows<E: Field>(
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    c_chunk: &mut [E],
    k: usize,
    n: usize,
) {
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for (ci, i) in rows.clone().enumerate() {
            let a_row = &a[i * k..(i + 1) * k];
            let c_row = &mut c_chunk[ci * n..(ci + 1) * n];
            for kk in k0..k1 {
                let aik = a_row[kk];
                if aik == E::ZERO {
                    continue;
                }
                axpy_row(c_row, aik, &b[kk * n..(kk + 1) * n]);
            }
        }
    }
}

/// Serial row-range kernel for `C = Aᴴ·B` (A: k×m, B: k×n), writing rows
/// `rows` of the m×n output into `c_chunk` (pre-zeroed). On real fields
/// the conjugation is the identity and this is the `Aᵀ·B` kernel.
pub(crate) fn ah_b_rows<E: Field>(
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    c_chunk: &mut [E],
    k: usize,
    m: usize,
    n: usize,
) {
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for kk in k0..k1 {
            let a_row = &a[kk * m..(kk + 1) * m];
            let b_row = &b[kk * n..(kk + 1) * n];
            for (ci, i) in rows.clone().enumerate() {
                let aki = a_row[i].conj();
                if aki == E::ZERO {
                    continue;
                }
                axpy_row(&mut c_chunk[ci * n..(ci + 1) * n], aki, b_row);
            }
        }
    }
}

/// Serial row-range kernel for `C = A·Bᴴ` (A: m×k, B: n×k), writing rows
/// `rows` of the m×n output into `c_chunk` (assignment, no pre-zeroing
/// needed). Real fields: the `A·Bᵀ` kernel.
pub(crate) fn a_bh_rows<E: Field>(
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    c_chunk: &mut [E],
    k: usize,
    n: usize,
) {
    for (ci, i) in rows.enumerate() {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c_chunk[ci * n..(ci + 1) * n];
        for j in 0..n {
            c_row[j] = dot_row_conj(a_row, &b[j * k..(j + 1) * k]);
        }
    }
}

/// How an operand enters a [`gemm`] product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Use the operand as stored.
    N,
    /// Use the conjugate transpose (plain transpose on real fields).
    H,
}

/// `C = op(A) · op(B)`, allocating the output.
///
/// The one matmul entry point: every named product (`matmul`,
/// `matmul_ah_b`, …) is an `#[inline]` alias onto this. Row kernels are
/// dispatched through the runtime-selected
/// [`StepKernel`](crate::linalg::StepKernel) for `E`.
pub fn gemm<E: Field>(opa: Op, opb: Op, a: &Mat<E>, b: &Mat<E>) -> Mat<E> {
    let m = match opa {
        Op::N => a.rows(),
        Op::H => a.cols(),
    };
    let n = match opb {
        Op::N => b.cols(),
        Op::H => b.rows(),
    };
    let mut c = Mat::zeros(m, n);
    gemm_into(opa, opb, a, b, &mut c);
    c
}

/// `C = op(A) · op(B)` into a preallocated output (zeroed here).
pub fn gemm_into<E: Field>(opa: Op, opb: Op, a: &Mat<E>, b: &Mat<E>, c: &mut Mat<E>) {
    let kern = E::step_kernel();
    match (opa, opb) {
        (Op::N, Op::N) => {
            let (m, k) = a.shape();
            let (k2, n) = b.shape();
            assert_eq!(k, k2, "gemm(N,N) inner dim mismatch: {k} vs {k2}");
            assert_eq!(c.shape(), (m, n), "gemm(N,N) output shape mismatch");
            c.as_mut_slice().fill(E::ZERO);

            let a_data = a.as_slice();
            let b_data = b.as_slice();
            if !worth_parallelizing(2 * m * n * k) {
                kern.mm_rows(a_data, b_data, 0..m, c.as_mut_slice(), k, n);
            } else {
                pool::parallel_rows(c.as_mut_slice(), m, n, |rows, chunk| {
                    kern.mm_rows(a_data, b_data, rows, chunk, k, n)
                });
            }
        }
        (Op::H, Op::N) => {
            // A is (k × m), read row-wise as a rank-1 accumulation over k
            // so no strided access: worker for C rows `rows` scans all k,
            // using conj(A[kk, i]) as the scalar.
            let (k, m) = a.shape();
            let (k2, n) = b.shape();
            assert_eq!(k, k2, "gemm(H,N) inner dim mismatch: {k} vs {k2}");
            assert_eq!(c.shape(), (m, n), "gemm(H,N) output shape mismatch");
            c.as_mut_slice().fill(E::ZERO);

            let a_data = a.as_slice();
            let b_data = b.as_slice();
            if !worth_parallelizing(2 * m * n * k) {
                kern.ah_b_rows(a_data, b_data, 0..m, c.as_mut_slice(), k, m, n);
            } else {
                pool::parallel_rows(c.as_mut_slice(), m, n, |rows, chunk| {
                    kern.ah_b_rows(a_data, b_data, rows, chunk, k, m, n)
                });
            }
        }
        (Op::N, Op::H) => {
            // B is (n × k); the inner loop is a conjugated dot product of
            // two contiguous rows. Pure assignment — no pre-zeroing needed.
            let (m, k) = a.shape();
            let (n, k2) = b.shape();
            assert_eq!(k, k2, "gemm(N,H) inner dim mismatch: {k} vs {k2}");
            assert_eq!(c.shape(), (m, n), "gemm(N,H) output shape mismatch");

            let a_data = a.as_slice();
            let b_data = b.as_slice();
            if !worth_parallelizing(2 * m * n * k) {
                kern.a_bh_rows(a_data, b_data, 0..m, c.as_mut_slice(), k, n);
            } else {
                pool::parallel_rows(c.as_mut_slice(), m, n, |rows, chunk| {
                    kern.a_bh_rows(a_data, b_data, rows, chunk, k, n)
                });
            }
        }
        (Op::H, Op::H) => {
            // C = Aᴴ·Bᴴ = (B·A)ᴴ: form T = B·A through the (N,N) path,
            // then write the conjugate transpose. No orthoptimizer product
            // has this shape — it exists so the API is total.
            let (k, m) = a.shape();
            let (n, k2) = b.shape();
            assert_eq!(k, k2, "gemm(H,H) inner dim mismatch: {k} vs {k2}");
            assert_eq!(c.shape(), (m, n), "gemm(H,H) output shape mismatch");
            let mut t = Mat::zeros(n, m);
            gemm_into(Op::N, Op::N, b, a, &mut t);
            for i in 0..m {
                for j in 0..n {
                    c[(i, j)] = t[(j, i)].conj();
                }
            }
        }
    }
}

/// `C = A · B` — alias of `gemm(N, N, ..)`.
#[inline]
pub fn matmul<E: Field>(a: &Mat<E>, b: &Mat<E>) -> Mat<E> {
    gemm(Op::N, Op::N, a, b)
}

/// `C = Aᴴ · B` — alias of `gemm(H, N, ..)`.
#[inline]
pub fn matmul_ah_b<E: Field>(a: &Mat<E>, b: &Mat<E>) -> Mat<E> {
    gemm(Op::H, Op::N, a, b)
}

/// `C = A · Bᴴ` — alias of `gemm(N, H, ..)`.
#[inline]
pub fn matmul_a_bh<E: Field>(a: &Mat<E>, b: &Mat<E>) -> Mat<E> {
    gemm(Op::N, Op::H, a, b)
}

/// `C = Aᵀ · B` — the real-field alias of [`matmul_ah_b`] (conjugation is
/// the identity on an ordered scalar).
#[inline]
pub fn matmul_at_b<S: Scalar>(a: &Mat<S>, b: &Mat<S>) -> Mat<S> {
    gemm(Op::H, Op::N, a, b)
}

/// `C = A · Bᵀ` — the real-field alias of [`matmul_a_bh`].
#[inline]
pub fn matmul_a_bt<S: Scalar>(a: &Mat<S>, b: &Mat<S>) -> Mat<S> {
    gemm(Op::N, Op::H, a, b)
}

/// `C = A · B` into a preallocated output — alias of `gemm_into(N, N, ..)`.
#[inline]
pub fn matmul_into<E: Field>(a: &Mat<E>, b: &Mat<E>, c: &mut Mat<E>) {
    gemm_into(Op::N, Op::N, a, b, c)
}

/// `C = Aᴴ · B` into a preallocated output — alias of `gemm_into(H, N, ..)`.
#[inline]
pub fn matmul_ah_b_into<E: Field>(a: &Mat<E>, b: &Mat<E>, c: &mut Mat<E>) {
    gemm_into(Op::H, Op::N, a, b, c)
}

/// `C = A · Bᴴ` into a preallocated output — alias of `gemm_into(N, H, ..)`.
#[inline]
pub fn matmul_a_bh_into<E: Field>(a: &Mat<E>, b: &Mat<E>, c: &mut Mat<E>) {
    gemm_into(Op::N, Op::H, a, b, c)
}

/// Real-field aliases of the `_into` entry points.
#[inline]
pub fn matmul_at_b_into<S: Scalar>(a: &Mat<S>, b: &Mat<S>, c: &mut Mat<S>) {
    gemm_into(Op::H, Op::N, a, b, c)
}

#[inline]
pub fn matmul_a_bt_into<S: Scalar>(a: &Mat<S>, b: &Mat<S>, c: &mut Mat<S>) {
    gemm_into(Op::N, Op::H, a, b, c)
}

/// `c += alpha * b` over a row; written with 8-wide unrolling so LLVM emits
/// fused SIMD adds.
#[inline]
fn axpy_row<E: Field>(c: &mut [E], alpha: E, b: &[E]) {
    debug_assert_eq!(c.len(), b.len());
    let n = c.len();
    let chunks = n / 8;
    for ch in 0..chunks {
        let base = ch * 8;
        // Manual unroll: the bounds are provably in-range so this compiles
        // branch-free.
        for u in 0..8 {
            c[base + u] += alpha * b[base + u];
        }
    }
    for idx in chunks * 8..n {
        c[idx] += alpha * b[idx];
    }
}

/// Conjugated dot product `Σ a_i · conj(b_i)` with 8 independent
/// accumulators (breaks the fp-add dependency chain; vectorizes well).
/// Real fields: a plain dot product.
///
/// The accumulator layout is a cross-kernel contract: the AVX2/NEON dot
/// products in `linalg::simd` keep one vector lane per accumulator (one
/// 8-lane f32 register, two 4-lane f64 registers, …) and reduce in the
/// same left-fold order `acc0 + acc1 + … + acc7 + tail`, which is what
/// makes kernel selection bit-transparent.
#[inline]
fn dot_row_conj<E: Field>(a: &[E], b: &[E]) -> E {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = [E::ZERO; 8];
    let chunks = n / 8;
    for ch in 0..chunks {
        let base = ch * 8;
        for u in 0..8 {
            acc[u] += a[base + u].mul_conj(b[base + u]);
        }
    }
    let mut s = acc[0];
    for &av in &acc[1..] {
        s += av;
    }
    let mut tail = E::ZERO;
    for idx in chunks * 8..n {
        tail += a[idx].mul_conj(b[idx]);
    }
    s + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Complex;
    use crate::rng::Rng;

    fn naive(a: &Mat<f64>, b: &Mat<f64>) -> Mat<f64> {
        let (m, k) = a.shape();
        let n = b.cols();
        Mat::from_fn(m, n, |i, j| (0..k).map(|kk| a[(i, kk)] * b[(kk, j)]).sum())
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from_u64(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 9, 23), (64, 64, 64)] {
            let a = Mat::<f64>::randn(m, k, &mut rng);
            let b = Mat::<f64>::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            let r = naive(&a, &b);
            assert!(c.sub(&r).max_abs() < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn at_b_matches_transpose_then_matmul() {
        let mut rng = Rng::seed_from_u64(1);
        let a = Mat::<f64>::randn(13, 7, &mut rng);
        let b = Mat::<f64>::randn(13, 11, &mut rng);
        let c = matmul_at_b(&a, &b);
        let r = naive(&a.transpose(), &b);
        assert!(c.sub(&r).max_abs() < 1e-10);
    }

    #[test]
    fn a_bt_matches_transpose_then_matmul() {
        let mut rng = Rng::seed_from_u64(2);
        let a = Mat::<f64>::randn(9, 15, &mut rng);
        let b = Mat::<f64>::randn(12, 15, &mut rng);
        let c = matmul_a_bt(&a, &b);
        let r = naive(&a, &b.transpose());
        assert!(c.sub(&r).max_abs() < 1e-10);
    }

    #[test]
    fn large_parallel_path_agrees_with_naive() {
        let mut rng = Rng::seed_from_u64(3);
        // Big enough to trip PAR_FLOPS.
        let a = Mat::<f64>::randn(160, 170, &mut rng);
        let b = Mat::<f64>::randn(170, 180, &mut rng);
        let c = matmul(&a, &b);
        let r = naive(&a, &b);
        assert!(c.sub(&r).max_abs() < 1e-9);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::seed_from_u64(4);
        let a = Mat::<f64>::randn(8, 8, &mut rng);
        assert!(matmul(&a, &Mat::eye(8)).sub(&a).max_abs() < 1e-12);
        assert!(matmul(&Mat::eye(8), &a).sub(&a).max_abs() < 1e-12);
    }

    #[test]
    fn tiny_matmuls_never_parallelize() {
        // Regression for the Fig. 1 regime: the parallel threshold is
        // derived from the call's OWN 2·m·n·k work, so a 3×3 product (54
        // flops) — or any small per-matrix shape — never spawns threads.
        // Batch parallelism over thousands of such matrices belongs to
        // `linalg::batch`, one worker per batch chunk.
        assert!(!worth_parallelizing(2 * 3 * 3 * 3));
        assert!(!worth_parallelizing(2 * 64 * 64 * 64));
        // The Fig. 4-scale shapes do cross it.
        assert!(worth_parallelizing(2 * 160 * 170 * 180));
        // Exactly at the boundary (2^22 flops) we parallelize.
        assert!(worth_parallelizing(1 << 22));
        assert!(!worth_parallelizing((1 << 22) - 1));
    }

    #[test]
    fn serial_kernels_match_entry_points() {
        // The row-range kernels are the shared substrate of both the
        // single-matrix entry points and the batched engine; drive them
        // directly over the full row range and compare.
        let mut rng = Rng::seed_from_u64(6);
        let (m, k, n) = (7, 11, 9);
        let a = Mat::<f64>::randn(m, k, &mut rng);
        let b = Mat::<f64>::randn(k, n, &mut rng);
        let mut c = Mat::<f64>::zeros(m, n);
        mm_rows(a.as_slice(), b.as_slice(), 0..m, c.as_mut_slice(), k, n);
        assert!(c.sub(&matmul(&a, &b)).max_abs() == 0.0);

        let at = Mat::<f64>::randn(k, m, &mut rng);
        let mut c2 = Mat::<f64>::zeros(m, n);
        ah_b_rows(at.as_slice(), b.as_slice(), 0..m, c2.as_mut_slice(), k, m, n);
        assert!(c2.sub(&matmul_at_b(&at, &b)).max_abs() == 0.0);

        let bt = Mat::<f64>::randn(n, k, &mut rng);
        let mut c3 = Mat::<f64>::zeros(m, n);
        a_bh_rows(a.as_slice(), bt.as_slice(), 0..m, c3.as_mut_slice(), k, n);
        assert!(c3.sub(&matmul_a_bt(&a, &bt)).max_abs() == 0.0);
    }

    #[test]
    fn gemm_aliases_are_bit_identical() {
        // The named entry points are #[inline] wrappers over gemm; drive
        // both spellings and require exact equality.
        let mut rng = Rng::seed_from_u64(9);
        let (m, k, n) = (6, 10, 8);
        let a = Mat::<f64>::randn(m, k, &mut rng);
        let b = Mat::<f64>::randn(k, n, &mut rng);
        assert!(gemm(Op::N, Op::N, &a, &b).sub(&matmul(&a, &b)).max_abs() == 0.0);

        let at = Mat::<f64>::randn(k, m, &mut rng);
        assert!(gemm(Op::H, Op::N, &at, &b).sub(&matmul_at_b(&at, &b)).max_abs() == 0.0);

        let bt = Mat::<f64>::randn(n, k, &mut rng);
        assert!(gemm(Op::N, Op::H, &a, &bt).sub(&matmul_a_bt(&a, &bt)).max_abs() == 0.0);
    }

    #[test]
    fn gemm_hh_matches_adjoint_composition() {
        // (H,H) is the one shape with no dedicated kernel: C = Aᴴ·Bᴴ must
        // equal the materialized-transpose composition.
        let mut rng = Rng::seed_from_u64(10);
        let a = Mat::<f64>::randn(7, 5, &mut rng); // op(A): 5×7
        let b = Mat::<f64>::randn(9, 7, &mut rng); // op(B): 7×9
        let c = gemm(Op::H, Op::H, &a, &b);
        let r = naive(&a.transpose(), &b.transpose());
        assert!(c.sub(&r).max_abs() < 1e-12);
    }

    #[test]
    fn complex_gemm_hh_conjugates() {
        let mut rng = Rng::seed_from_u64(11);
        let a = CM::randn(6, 4, &mut rng); // op(A): 4×6
        let b = CM::randn(5, 6, &mut rng); // op(B): 6×5
        let fast = gemm(Op::H, Op::H, &a, &b);
        let slow = matmul(&a.adjoint(), &b.adjoint());
        assert!(cnorm(&fast.sub(&slow)) < 1e-10);
    }

    #[test]
    fn f32_path_reasonable_accuracy() {
        let mut rng = Rng::seed_from_u64(5);
        let a = Mat::<f32>::randn(33, 47, &mut rng);
        let b = Mat::<f32>::randn(47, 29, &mut rng);
        let c = matmul(&a, &b);
        let cd = matmul(&a.cast::<f64>(), &b.cast::<f64>());
        assert!(c.cast::<f64>().sub(&cd).max_abs() < 1e-3);
    }

    // ---- Complex-field kernels. -----------------------------------------

    type CM = Mat<Complex<f64>>;

    fn cnorm(a: &CM) -> f64 {
        a.norm().to_f64()
    }

    #[test]
    fn complex_matmul_matches_manual_small() {
        // (1+2i)(3+4i) = -5+10i
        let a = CM::from_vec(1, 1, vec![Complex::new(1.0, 2.0)]);
        let b = CM::from_vec(1, 1, vec![Complex::new(3.0, 4.0)]);
        let c = matmul(&a, &b);
        assert!((c[(0, 0)].re + 5.0).abs() < 1e-12);
        assert!((c[(0, 0)].im - 10.0).abs() < 1e-12);
    }

    #[test]
    fn complex_a_bh_consistent_with_adjoint_matmul() {
        let mut rng = Rng::seed_from_u64(7);
        let a = CM::randn(3, 8, &mut rng);
        let b = CM::randn(5, 8, &mut rng);
        let fast = matmul_a_bh(&a, &b);
        let slow = matmul(&a, &b.adjoint());
        assert!(cnorm(&fast.sub(&slow)) < 1e-10);
    }

    #[test]
    fn complex_ah_b_consistent_with_adjoint_matmul() {
        let mut rng = Rng::seed_from_u64(8);
        let a = CM::randn(8, 3, &mut rng);
        let b = CM::randn(8, 5, &mut rng);
        let fast = matmul_ah_b(&a, &b);
        let slow = matmul(&a.adjoint(), &b);
        assert!(cnorm(&fast.sub(&slow)) < 1e-10);
    }
}
