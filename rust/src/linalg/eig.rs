//! Cyclic Jacobi eigensolver for symmetric matrices.
//!
//! Used to compute the *analytic optima* of the Fig. 4 workloads (online
//! PCA's optimal loss = sum of the top-p eigenvalues of A Aᵀ) so the
//! optimality-gap metric has an exact reference, and by the synthetic
//! spectrum generator (condition number 1000, exponentially decaying
//! eigenvalues — §5.1).

use super::mat::Mat;
use super::matmul::matmul;
use super::scalar::Scalar;

/// Result of a symmetric eigendecomposition `A = V diag(w) Vᵀ`,
/// eigenvalues sorted descending, eigenvectors in the *columns* of `v`.
pub struct SymEig<S: Scalar> {
    pub values: Vec<S>,
    pub vectors: Mat<S>,
}

/// Cyclic Jacobi with threshold sweeps. `O(n³)` per sweep; fine for the
/// reference-optimum computations (n ≤ ~1000 in default configs).
pub fn sym_eig<S: Scalar>(a: &Mat<S>, max_sweeps: usize) -> SymEig<S> {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "sym_eig expects a square matrix");
    let mut m = a.clone();
    let mut v = Mat::<S>::eye(n);

    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                let x = m[(i, j)].to_f64();
                off += 2.0 * x * x;
            }
        }
        if off.sqrt() < 1e-11 * (1.0 + m.norm().to_f64()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)].to_f64();
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)].to_f64();
                let aqq = m[(q, q)].to_f64();
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                let (cs, ss) = (S::from_f64(c), S::from_f64(s));
                // Rotate rows/cols p, q of m: m = Jᵀ m J.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = cs * mkp - ss * mkq;
                    m[(k, q)] = ss * mkp + cs * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = cs * mpk - ss * mqk;
                    m[(q, k)] = ss * mpk + cs * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = cs * vkp - ss * vkq;
                    v[(k, q)] = ss * vkp + cs * vkq;
                }
            }
        }
    }

    // Extract and sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)].to_f64()).collect();
    order.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
    let values: Vec<S> = order.iter().map(|&i| m[(i, i)]).collect();
    let vectors = Mat::from_fn(n, n, |r, c| v[(r, order[c])]);
    SymEig { values, vectors }
}

/// Build a symmetric PSD matrix with a prescribed (descending) spectrum
/// and random orthogonal eigenbasis: `A = Q diag(w) Qᵀ`.
pub fn with_spectrum<S: Scalar>(spectrum: &[S], rng: &mut crate::rng::Rng) -> Mat<S> {
    let n = spectrum.len();
    let q = super::qr::qr_thin(&Mat::<S>::randn(n, n, rng));
    // A = Q diag(w) Qᵀ
    let mut qw = q.clone();
    for i in 0..n {
        for j in 0..n {
            qw[(i, j)] *= spectrum[j];
        }
    }
    matmul(&qw, &q.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut d = Mat::<f64>::zeros(4, 4);
        for (i, &w) in [4.0, 1.0, 3.0, 2.0].iter().enumerate() {
            d[(i, i)] = w;
        }
        let e = sym_eig(&d, 30);
        let got: Vec<f64> = e.values.clone();
        assert!((got[0] - 4.0).abs() < 1e-9);
        assert!((got[3] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::seed_from_u64(0);
        let g = Mat::<f64>::randn(8, 8, &mut rng);
        let a = g.add(&g.transpose()); // symmetric
        let e = sym_eig(&a, 50);
        // A ≈ V diag(w) Vᵀ
        let mut vw = e.vectors.clone();
        for i in 0..8 {
            for j in 0..8 {
                vw[(i, j)] *= e.values[j];
            }
        }
        let rec = matmul(&vw, &e.vectors.transpose());
        assert!(rec.sub(&a).max_abs() < 1e-8, "err={}", rec.sub(&a).max_abs());
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Rng::seed_from_u64(1);
        let g = Mat::<f64>::randn(10, 10, &mut rng);
        let a = g.add(&g.transpose());
        let e = sym_eig(&a, 50);
        let mut vtv = crate::linalg::matmul_at_b(&e.vectors, &e.vectors);
        vtv.sub_eye_inplace();
        assert!(vtv.max_abs() < 1e-9);
    }

    #[test]
    fn with_spectrum_roundtrip() {
        let mut rng = Rng::seed_from_u64(2);
        let spec = vec![10.0, 5.0, 2.0, 1.0, 0.5];
        let a = with_spectrum(&spec, &mut rng);
        let e = sym_eig(&a, 50);
        for (got, want) in e.values.iter().zip(&spec) {
            assert!((got - want).abs() < 1e-8, "{got} vs {want}");
        }
    }
}
