//! Polar projection onto the Stiefel manifold via Newton–Schulz.
//!
//! For a wide matrix `X (p × n)` with full row rank, the polar factor
//! `U = (X Xᵀ)^{-1/2} X` is the *closest* row-orthonormal matrix in
//! Frobenius norm. Newton–Schulz iterates `Y ← 1.5 Y − 0.5 (Y Yᵀ) Y`,
//! which converges quadratically when every singular value lies in
//! `(0, √3)`; we pre-scale by the spectral norm estimate to guarantee it.
//!
//! Matmul-only, so unlike QR/SVD it *is* accelerator-friendly — which is
//! exactly why the POGO normal step (λ = 1/2) is its first-order Taylor
//! truncation (paper §3.3 intuition / SLPG connection in §B).

use super::complexmat::CMat;
use super::mat::Mat;
use super::matmul::{matmul, matmul_a_bt};
use super::norms::spectral_norm_est;
use super::scalar::Scalar;

/// Options for the Newton–Schulz polar projection.
#[derive(Clone, Copy, Debug)]
pub struct PolarOpts {
    /// Stop when `‖Y Yᵀ − I‖_F` falls below this.
    pub tol: f64,
    /// Hard iteration cap.
    pub max_iters: usize,
}

impl Default for PolarOpts {
    fn default() -> Self {
        PolarOpts { tol: 1e-7, max_iters: 60 }
    }
}

/// Project a wide real matrix onto St(p, n) (row-orthonormal polar factor).
pub fn polar_project<S: Scalar>(x: &Mat<S>, opts: PolarOpts) -> Mat<S> {
    let (p, n) = x.shape();
    assert!(p <= n, "polar_project expects a wide matrix, got {p}x{n}");
    // Pre-scale into the convergence region: σ_max(Y0) ≈ 1.
    let sigma = spectral_norm_est(x, 20).max(1e-30);
    let mut y = x.scale(S::from_f64(1.0 / sigma));
    for _ in 0..opts.max_iters {
        let mut g = matmul_a_bt(&y, &y); // p×p
        g.sub_eye_inplace();
        let err = g.norm().to_f64();
        if err < opts.tol {
            break;
        }
        // Y ← 1.5 Y − 0.5 (Y Yᵀ) Y. With g = Y Yᵀ − I this simplifies to
        // Y ← Y − 0.5 g Y, saving one p×p add.
        let gy = matmul(&g, &y);
        y.axpy(S::from_f64(-0.5), &gy);
    }
    y
}

/// Project a wide complex matrix onto the complex Stiefel manifold
/// (`X X^H = I_p`), same Newton–Schulz scheme over `CMat`.
pub fn polar_project_complex<S: Scalar>(x: &CMat<S>, opts: PolarOpts) -> CMat<S> {
    let (p, n) = x.shape();
    assert!(p <= n, "polar_project_complex expects a wide matrix, got {p}x{n}");
    let sigma = x.spectral_norm_est(20).max(1e-30);
    let mut y = x.scale_re(S::from_f64(1.0 / sigma));
    for _ in 0..opts.max_iters {
        let mut g = y.matmul_a_bh(&y); // p×p, Hermitian
        g.sub_eye_inplace();
        if g.norm().to_f64() < opts.tol {
            break;
        }
        let gy = g.matmul(&y);
        y.axpy_re(S::from_f64(-0.5), &gy);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn projects_onto_manifold() {
        let mut rng = Rng::seed_from_u64(0);
        for &(p, n) in &[(3, 3), (5, 12), (20, 31)] {
            let x = Mat::<f64>::randn(p, n, &mut rng);
            let y = polar_project(&x, PolarOpts::default());
            let mut g = matmul_a_bt(&y, &y);
            g.sub_eye_inplace();
            assert!(g.norm().to_f64() < 1e-6, "({p},{n}): {}", g.norm());
        }
    }

    #[test]
    fn fixed_point_on_manifold() {
        let mut rng = Rng::seed_from_u64(1);
        let x0 = Mat::<f64>::randn(6, 10, &mut rng);
        let y = polar_project(&x0, PolarOpts::default());
        let y2 = polar_project(&y, PolarOpts::default());
        assert!(y2.sub(&y).max_abs() < 1e-6);
    }

    #[test]
    fn polar_is_closest_vs_qr() {
        // The polar factor minimizes ‖X − U‖_F over St; check it beats the
        // QR factor on a random instance (generic position).
        let mut rng = Rng::seed_from_u64(2);
        let x = Mat::<f64>::randn(4, 8, &mut rng);
        let up = polar_project(&x, PolarOpts { tol: 1e-12, max_iters: 200 });
        let uq = crate::linalg::qr_retract_rows(&x);
        let dp = up.sub(&x).norm();
        let dq = uq.sub(&x).norm();
        assert!(dp <= dq + 1e-9, "polar {dp} vs qr {dq}");
    }

    #[test]
    fn complex_projects_onto_manifold() {
        let mut rng = Rng::seed_from_u64(3);
        let x = CMat::<f64>::randn(4, 9, &mut rng);
        let y = polar_project_complex(&x, PolarOpts::default());
        let mut g = y.matmul_a_bh(&y);
        g.sub_eye_inplace();
        assert!(g.norm().to_f64() < 1e-6, "{}", g.norm());
    }
}
