//! Polar projection onto the (real or complex) Stiefel manifold via
//! Newton–Schulz.
//!
//! For a wide matrix `X (p × n)` with full row rank, the polar factor
//! `U = (X Xᴴ)^{-1/2} X` is the *closest* row-orthonormal matrix in
//! Frobenius norm. Newton–Schulz iterates `Y ← 1.5 Y − 0.5 (Y Yᴴ) Y`,
//! which converges quadratically when every singular value lies in
//! `(0, √3)`; we pre-scale by the spectral norm estimate to guarantee it.
//!
//! Matmul-only, so unlike QR/SVD it *is* accelerator-friendly — which is
//! exactly why the POGO normal step (λ = 1/2) is its first-order Taylor
//! truncation (paper §3.3 intuition / SLPG connection in §B). The one
//! generic implementation covers both fields: on the complex Stiefel
//! manifold it is the retraction the complex RGD baseline uses in place
//! of complex Householder QR (recorded in DESIGN.md).

use super::mat::Mat;
use super::matmul::{matmul, matmul_a_bh};
use super::norms::spectral_norm_est;
use super::scalar::{Field, Scalar};

/// Options for the Newton–Schulz polar projection.
#[derive(Clone, Copy, Debug)]
pub struct PolarOpts {
    /// Stop when `‖Y Yᴴ − I‖_F` falls below this.
    pub tol: f64,
    /// Hard iteration cap.
    pub max_iters: usize,
}

impl Default for PolarOpts {
    fn default() -> Self {
        PolarOpts { tol: 1e-7, max_iters: 60 }
    }
}

/// Project a wide matrix onto the Stiefel manifold of its field
/// (row-orthonormal polar factor; `X Xᴴ = I`).
pub fn polar_project<E: Field>(x: &Mat<E>, opts: PolarOpts) -> Mat<E> {
    let (p, n) = x.shape();
    assert!(p <= n, "polar_project expects a wide matrix, got {p}x{n}");
    // Pre-scale into the convergence region: σ_max(Y0) ≈ 1.
    let sigma = spectral_norm_est(x, 20).max(1e-30);
    let mut y = x.scale(E::from_f64(1.0 / sigma));
    for _ in 0..opts.max_iters {
        let mut g = matmul_a_bh(&y, &y); // p×p
        g.sub_eye_inplace();
        let err = g.norm().to_f64();
        if err < opts.tol {
            break;
        }
        // Y ← 1.5 Y − 0.5 (Y Yᴴ) Y. With g = Y Yᴴ − I this simplifies to
        // Y ← Y − 0.5 g Y, saving one p×p add.
        let gy = matmul(&g, &y);
        y.axpy(E::from_f64(-0.5), &gy);
    }
    y
}

/// Back-compat name for the complex instantiation (`X Xᴴ = I_p`). The
/// implementation is [`polar_project`] — one Newton–Schulz over `Field`.
pub fn polar_project_complex<S: Scalar>(
    x: &super::complexmat::CMat<S>,
    opts: PolarOpts,
) -> super::complexmat::CMat<S> {
    polar_project(x, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CMat;
    use crate::rng::Rng;

    #[test]
    fn projects_onto_manifold() {
        let mut rng = Rng::seed_from_u64(0);
        for &(p, n) in &[(3, 3), (5, 12), (20, 31)] {
            let x = Mat::<f64>::randn(p, n, &mut rng);
            let y = polar_project(&x, PolarOpts::default());
            let mut g = matmul_a_bh(&y, &y);
            g.sub_eye_inplace();
            assert!(g.norm().to_f64() < 1e-6, "({p},{n}): {}", g.norm());
        }
    }

    #[test]
    fn fixed_point_on_manifold() {
        let mut rng = Rng::seed_from_u64(1);
        let x0 = Mat::<f64>::randn(6, 10, &mut rng);
        let y = polar_project(&x0, PolarOpts::default());
        let y2 = polar_project(&y, PolarOpts::default());
        assert!(y2.sub(&y).max_abs() < 1e-6);
    }

    #[test]
    fn polar_is_closest_vs_qr() {
        // The polar factor minimizes ‖X − U‖_F over St; check it beats the
        // QR factor on a random instance (generic position).
        let mut rng = Rng::seed_from_u64(2);
        let x = Mat::<f64>::randn(4, 8, &mut rng);
        let up = polar_project(&x, PolarOpts { tol: 1e-12, max_iters: 200 });
        let uq = crate::linalg::qr_retract_rows(&x);
        let dp = up.sub(&x).norm();
        let dq = uq.sub(&x).norm();
        assert!(dp <= dq + 1e-9, "polar {dp} vs qr {dq}");
    }

    #[test]
    fn complex_projects_onto_manifold() {
        let mut rng = Rng::seed_from_u64(3);
        let x = CMat::<f64>::randn(4, 9, &mut rng);
        let y = polar_project_complex(&x, PolarOpts::default());
        let mut g = matmul_a_bh(&y, &y);
        g.sub_eye_inplace();
        assert!(g.norm().to_f64() < 1e-6, "{}", g.norm());
    }
}
