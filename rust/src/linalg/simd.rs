//! Arch-specific `StepKernel` microkernels (AVX2 on `x86_64`, NEON on
//! `aarch64`), always compiled on their arch and selected at runtime by
//! `step_kernel::select_f32` / `select_f64` after feature detection.
//!
//! **Lane-exact by construction.** Each microkernel performs the same
//! arithmetic in the same order as the portable kernel: the vector
//! accumulators map lane-for-lane onto the portable kernel's 8
//! independent dot accumulators, horizontal reduction sums the lanes in
//! the portable order, and products use multiply-then-add rather than
//! FMA (a fused multiply-add rounds once where mul+add rounds twice, so
//! contraction would make kernel selection observable). That makes
//! kernel choice bit-transparent — the property the batched↔loop and
//! fused↔naive parity suites, checkpoint replay, and serve's
//! bit-identical-jobs guarantee all lean on. The win over the portable
//! kernel is guaranteed vectorization (independent of LLVM's
//! autovectorizer heuristics) and pointer-based inner loops with no
//! bounds checks.
//!
//! Safety: the `#[target_feature]` functions here are only reachable
//! through the `AVX2` / `NEON` statics, which the selector hands out
//! strictly after `is_x86_feature_detected!` / NEON detection succeeds.

#![allow(clippy::missing_safety_doc)]

/// Shared row-loop skeleton over an arch-specific `axpy`/`dot` pair.
/// Mirrors `matmul::{mm_rows, ah_b_rows, a_bh_rows}` exactly (same KB
/// blocking, same zero-skip) so only the innermost vector ops differ.
macro_rules! impl_simd_step_kernel {
    ($kern:ty, $label:expr, $elem:ty, $axpy:path, $dot:path) => {
        impl crate::linalg::step_kernel::StepKernel<$elem> for $kern {
            fn name(&self) -> &'static str {
                $label
            }

            fn mm_rows(
                &self,
                a: &[$elem],
                b: &[$elem],
                rows: std::ops::Range<usize>,
                c_chunk: &mut [$elem],
                k: usize,
                n: usize,
            ) {
                for k0 in (0..k).step_by(crate::linalg::matmul::KB) {
                    let k1 = (k0 + crate::linalg::matmul::KB).min(k);
                    for (ci, i) in rows.clone().enumerate() {
                        let a_row = &a[i * k..(i + 1) * k];
                        let c_row = &mut c_chunk[ci * n..(ci + 1) * n];
                        for kk in k0..k1 {
                            let aik = a_row[kk];
                            if aik == 0.0 {
                                continue;
                            }
                            // SAFETY: reachable only after feature detection.
                            unsafe { $axpy(c_row, aik, &b[kk * n..(kk + 1) * n]) };
                        }
                    }
                }
            }

            fn ah_b_rows(
                &self,
                a: &[$elem],
                b: &[$elem],
                rows: std::ops::Range<usize>,
                c_chunk: &mut [$elem],
                k: usize,
                m: usize,
                n: usize,
            ) {
                for k0 in (0..k).step_by(crate::linalg::matmul::KB) {
                    let k1 = (k0 + crate::linalg::matmul::KB).min(k);
                    for kk in k0..k1 {
                        let a_row = &a[kk * m..(kk + 1) * m];
                        let b_row = &b[kk * n..(kk + 1) * n];
                        for (ci, i) in rows.clone().enumerate() {
                            // Real field: conj is the identity.
                            let aki = a_row[i];
                            if aki == 0.0 {
                                continue;
                            }
                            // SAFETY: reachable only after feature detection.
                            unsafe { $axpy(&mut c_chunk[ci * n..(ci + 1) * n], aki, b_row) };
                        }
                    }
                }
            }

            fn a_bh_rows(
                &self,
                a: &[$elem],
                b: &[$elem],
                rows: std::ops::Range<usize>,
                c_chunk: &mut [$elem],
                k: usize,
                n: usize,
            ) {
                for (ci, i) in rows.enumerate() {
                    let a_row = &a[i * k..(i + 1) * k];
                    let c_row = &mut c_chunk[ci * n..(ci + 1) * n];
                    for j in 0..n {
                        // SAFETY: reachable only after feature detection.
                        c_row[j] = unsafe { $dot(a_row, &b[j * k..(j + 1) * k]) };
                    }
                }
            }
        }
    };
}

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use std::arch::x86_64::*;

    /// AVX2 microkernel for `f32`/`f64` (mul+add, never FMA — see the
    /// module docs for why contraction is deliberately avoided).
    pub struct Avx2Kernel;

    /// Selected by `step_kernel::select_*` after
    /// `is_x86_feature_detected!("avx2")`.
    pub static AVX2: Avx2Kernel = Avx2Kernel;

    /// `c += alpha·b`, 8 lanes per iteration. Elementwise, so any vector
    /// width gives bit-identical results to the scalar loop.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy_f32(c: &mut [f32], alpha: f32, b: &[f32]) {
        debug_assert_eq!(c.len(), b.len());
        let n = c.len();
        let cp = c.as_mut_ptr();
        let bp = b.as_ptr();
        let va = _mm256_set1_ps(alpha);
        let mut i = 0usize;
        while i + 8 <= n {
            let vb = _mm256_loadu_ps(bp.add(i));
            let vc = _mm256_loadu_ps(cp.add(i));
            _mm256_storeu_ps(cp.add(i), _mm256_add_ps(vc, _mm256_mul_ps(va, vb)));
            i += 8;
        }
        while i < n {
            *cp.add(i) += alpha * *bp.add(i);
            i += 1;
        }
    }

    /// `c += alpha·b`, 4 `f64` lanes per iteration.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy_f64(c: &mut [f64], alpha: f64, b: &[f64]) {
        debug_assert_eq!(c.len(), b.len());
        let n = c.len();
        let cp = c.as_mut_ptr();
        let bp = b.as_ptr();
        let va = _mm256_set1_pd(alpha);
        let mut i = 0usize;
        while i + 4 <= n {
            let vb = _mm256_loadu_pd(bp.add(i));
            let vc = _mm256_loadu_pd(cp.add(i));
            _mm256_storeu_pd(cp.add(i), _mm256_add_pd(vc, _mm256_mul_pd(va, vb)));
            i += 4;
        }
        while i < n {
            *cp.add(i) += alpha * *bp.add(i);
            i += 1;
        }
    }

    /// Dot product with one 8-lane accumulator: lane `u` holds exactly the
    /// portable kernel's accumulator `acc[u]`, and the horizontal sum
    /// reduces the lanes in the portable order (acc0 + acc1 + … + tail).
    #[target_feature(enable = "avx2")]
    unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let chunks = n / 8;
        for ch in 0..chunks {
            let base = ch * 8;
            let va = _mm256_loadu_ps(ap.add(base));
            let vb = _mm256_loadu_ps(bp.add(base));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = lanes[0];
        for &l in &lanes[1..] {
            s += l;
        }
        let mut tail = 0.0f32;
        for idx in chunks * 8..n {
            tail += *ap.add(idx) * *bp.add(idx);
        }
        s + tail
    }

    /// Dot product with two 4-lane accumulators covering the portable
    /// kernel's accumulators 0–3 and 4–7 per 8-element chunk.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let chunks = n / 8;
        for ch in 0..chunks {
            let base = ch * 8;
            acc0 = _mm256_add_pd(
                acc0,
                _mm256_mul_pd(_mm256_loadu_pd(ap.add(base)), _mm256_loadu_pd(bp.add(base))),
            );
            acc1 = _mm256_add_pd(
                acc1,
                _mm256_mul_pd(
                    _mm256_loadu_pd(ap.add(base + 4)),
                    _mm256_loadu_pd(bp.add(base + 4)),
                ),
            );
        }
        let mut lanes = [0.0f64; 8];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
        let mut s = lanes[0];
        for &l in &lanes[1..] {
            s += l;
        }
        let mut tail = 0.0f64;
        for idx in chunks * 8..n {
            tail += *ap.add(idx) * *bp.add(idx);
        }
        s + tail
    }

    impl_simd_step_kernel!(Avx2Kernel, "avx2", f32, axpy_f32, dot_f32);
    impl_simd_step_kernel!(Avx2Kernel, "avx2", f64, axpy_f64, dot_f64);
}

#[cfg(target_arch = "aarch64")]
pub(crate) mod arm {
    use std::arch::aarch64::*;

    /// NEON microkernel for `f32`/`f64` (explicit `vmulq` + `vaddq`, not
    /// `vmlaq`/FMLA — see the module docs on avoiding contraction).
    pub struct NeonKernel;

    /// Selected by `step_kernel::select_*` after NEON detection.
    pub static NEON: NeonKernel = NeonKernel;

    /// `c += alpha·b`, 4 `f32` lanes per iteration.
    #[target_feature(enable = "neon")]
    unsafe fn axpy_f32(c: &mut [f32], alpha: f32, b: &[f32]) {
        debug_assert_eq!(c.len(), b.len());
        let n = c.len();
        let cp = c.as_mut_ptr();
        let bp = b.as_ptr();
        let va = vdupq_n_f32(alpha);
        let mut i = 0usize;
        while i + 4 <= n {
            let vb = vld1q_f32(bp.add(i));
            let vc = vld1q_f32(cp.add(i));
            vst1q_f32(cp.add(i), vaddq_f32(vc, vmulq_f32(va, vb)));
            i += 4;
        }
        while i < n {
            *cp.add(i) += alpha * *bp.add(i);
            i += 1;
        }
    }

    /// `c += alpha·b`, 2 `f64` lanes per iteration.
    #[target_feature(enable = "neon")]
    unsafe fn axpy_f64(c: &mut [f64], alpha: f64, b: &[f64]) {
        debug_assert_eq!(c.len(), b.len());
        let n = c.len();
        let cp = c.as_mut_ptr();
        let bp = b.as_ptr();
        let va = vdupq_n_f64(alpha);
        let mut i = 0usize;
        while i + 2 <= n {
            let vb = vld1q_f64(bp.add(i));
            let vc = vld1q_f64(cp.add(i));
            vst1q_f64(cp.add(i), vaddq_f64(vc, vmulq_f64(va, vb)));
            i += 2;
        }
        while i < n {
            *cp.add(i) += alpha * *bp.add(i);
            i += 1;
        }
    }

    /// Dot product, two 4-lane accumulators = portable accumulators 0–3
    /// and 4–7 per 8-element chunk, reduced in the portable order.
    #[target_feature(enable = "neon")]
    unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let chunks = n / 8;
        for ch in 0..chunks {
            let base = ch * 8;
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(ap.add(base)), vld1q_f32(bp.add(base))));
            acc1 = vaddq_f32(
                acc1,
                vmulq_f32(vld1q_f32(ap.add(base + 4)), vld1q_f32(bp.add(base + 4))),
            );
        }
        let mut lanes = [0.0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        let mut s = lanes[0];
        for &l in &lanes[1..] {
            s += l;
        }
        let mut tail = 0.0f32;
        for idx in chunks * 8..n {
            tail += *ap.add(idx) * *bp.add(idx);
        }
        s + tail
    }

    /// Dot product, four 2-lane accumulators = portable accumulators
    /// (0,1), (2,3), (4,5), (6,7) per 8-element chunk.
    #[target_feature(enable = "neon")]
    unsafe fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = vdupq_n_f64(0.0);
        let mut acc1 = vdupq_n_f64(0.0);
        let mut acc2 = vdupq_n_f64(0.0);
        let mut acc3 = vdupq_n_f64(0.0);
        let chunks = n / 8;
        for ch in 0..chunks {
            let base = ch * 8;
            acc0 = vaddq_f64(acc0, vmulq_f64(vld1q_f64(ap.add(base)), vld1q_f64(bp.add(base))));
            acc1 = vaddq_f64(
                acc1,
                vmulq_f64(vld1q_f64(ap.add(base + 2)), vld1q_f64(bp.add(base + 2))),
            );
            acc2 = vaddq_f64(
                acc2,
                vmulq_f64(vld1q_f64(ap.add(base + 4)), vld1q_f64(bp.add(base + 4))),
            );
            acc3 = vaddq_f64(
                acc3,
                vmulq_f64(vld1q_f64(ap.add(base + 6)), vld1q_f64(bp.add(base + 6))),
            );
        }
        let mut lanes = [0.0f64; 8];
        vst1q_f64(lanes.as_mut_ptr(), acc0);
        vst1q_f64(lanes.as_mut_ptr().add(2), acc1);
        vst1q_f64(lanes.as_mut_ptr().add(4), acc2);
        vst1q_f64(lanes.as_mut_ptr().add(6), acc3);
        let mut s = lanes[0];
        for &l in &lanes[1..] {
            s += l;
        }
        let mut tail = 0.0f64;
        for idx in chunks * 8..n {
            tail += *ap.add(idx) * *bp.add(idx);
        }
        s + tail
    }

    impl_simd_step_kernel!(NeonKernel, "neon", f32, axpy_f32, dot_f32);
    impl_simd_step_kernel!(NeonKernel, "neon", f64, axpy_f64, dot_f64);
}
