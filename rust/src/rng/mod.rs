//! Reproducible pseudo-random number generation.
//!
//! The offline crate registry has no `rand`, so this module implements the
//! xoshiro256++ generator (Blackman & Vigna) plus the distributions the
//! rest of the crate needs: uniform floats, Box–Muller Gaussians, integer
//! ranges, shuffles and random orthogonal/unitary initialisation support.
//!
//! Everything in the repository that consumes randomness takes an explicit
//! `&mut Rng`, so every experiment is bit-reproducible given its seed.

/// xoshiro256++ PRNG. 256 bits of state, period 2^256 − 1.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// splitmix64, used to expand a 64-bit seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single u64 (via splitmix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream for a sub-task (e.g. one worker thread).
    /// Equivalent to re-seeding with `next_u64`s; streams do not overlap in
    /// practice for our usage sizes.
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply-shift; bias is negligible for our n (< 2^32).
        let x = self.next_u64();
        ((x as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard Gaussian via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // Avoid u == 0 for the log.
        let mut u = self.uniform();
        if u <= f64::MIN_POSITIVE {
            u = f64::MIN_POSITIVE;
        }
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Gaussian with given mean and standard deviation.
    #[inline]
    pub fn gaussian_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Fill a slice with standard Gaussians (f32).
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian() as f32;
        }
    }

    /// Fill a slice with standard Gaussians (f64).
    pub fn fill_gaussian_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.gaussian();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::seed_from_u64(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::seed_from_u64(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(11);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(13);
        let mut xs: Vec<usize> = (0..64).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut a = Rng::seed_from_u64(5);
        let mut f = a.fork();
        let same = (0..16).filter(|_| a.next_u64() == f.next_u64()).count();
        assert!(same < 2);
    }
}
