//! Experiment configuration: presets mirroring the paper's hyperparameters
//! (§C.1–C.4), JSON round-trip, and CLI overrides.
//!
//! Shapes default to the CPU-scaled sizes of `python/compile/shapes.py`
//! (the artifact manifest is the runtime source of truth); `--full`
//! switches the Fig. 4 experiments to the paper's exact sizes if the full
//! artifacts were built.

use crate::coordinator::OptimizerSpec;
use crate::optim::base::BaseOptKind;
use crate::optim::pogo::LambdaPolicy;
use crate::optim::{Engine, Method};
use crate::util::json::Json;

/// Which experiment (one per paper figure).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExperimentId {
    Fig4Pca,
    Fig4Procrustes,
    Fig5Ovit,
    Fig1CnnFilters,
    Fig1CnnKernels,
    Fig8Born,
    FigC1Precision,
    FigC2Lambda,
    ScaleMatrices,
}

impl ExperimentId {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "fig4-pca" => Self::Fig4Pca,
            "fig4-procrustes" | "fig4-proc" => Self::Fig4Procrustes,
            "fig5" | "fig5-ovit" => Self::Fig5Ovit,
            "fig1-filters" | "fig6-filters" => Self::Fig1CnnFilters,
            "fig1-kernels" | "fig7" | "fig6-kernels" => Self::Fig1CnnKernels,
            "fig8" | "fig8-born" => Self::Fig8Born,
            "figc1" | "precision" => Self::FigC1Precision,
            "figc2" | "lambda" => Self::FigC2Lambda,
            "scale" => Self::ScaleMatrices,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Fig4Pca => "fig4-pca",
            Self::Fig4Procrustes => "fig4-procrustes",
            Self::Fig5Ovit => "fig5-ovit",
            Self::Fig1CnnFilters => "fig1-filters",
            Self::Fig1CnnKernels => "fig1-kernels",
            Self::Fig8Born => "fig8-born",
            Self::FigC1Precision => "figc1",
            Self::FigC2Lambda => "figc2",
            Self::ScaleMatrices => "scale",
        }
    }

    pub fn all() -> &'static [ExperimentId] {
        &[
            Self::Fig4Pca,
            Self::Fig4Procrustes,
            Self::Fig5Ovit,
            Self::Fig1CnnFilters,
            Self::Fig1CnnKernels,
            Self::Fig8Born,
            Self::FigC1Precision,
            Self::FigC2Lambda,
            Self::ScaleMatrices,
        ]
    }
}

/// Top-level run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub experiment: ExperimentId,
    /// Methods to run (default: the experiment's paper lineup).
    pub methods: Vec<Method>,
    pub steps: usize,
    pub repetitions: usize,
    pub seed: u64,
    /// Output directory for CSV series.
    pub out_dir: std::path::PathBuf,
    /// Use the paper's full Fig. 4 shapes (requires full artifacts).
    pub full: bool,
    /// Shrink workloads for smoke runs.
    pub quick: bool,
    /// Explicit spec override (`pogo run --spec file.json`): replaces the
    /// paper preset for its method — see [`resolve_spec`].
    pub spec: Option<OptimizerSpec>,
}

impl RunConfig {
    pub fn new(experiment: ExperimentId) -> Self {
        RunConfig {
            experiment,
            methods: default_methods(experiment),
            steps: default_steps(experiment),
            repetitions: 1,
            seed: 0,
            out_dir: crate::repo_root().join("results"),
            full: false,
            quick: false,
            spec: None,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("experiment", Json::str(self.experiment.name())),
            ("methods", Json::arr(self.methods.iter().map(|m| Json::str(m.name())))),
            ("steps", Json::num(self.steps as f64)),
            ("repetitions", Json::num(self.repetitions as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("full", Json::Bool(self.full)),
            ("quick", Json::Bool(self.quick)),
            ("spec", self.spec.map_or(Json::Null, |s| s.to_json())),
        ])
    }
}

/// The spec actually used for `method` in a run: the `--spec` override
/// when it targets this method, the paper preset otherwise.
pub fn resolve_spec(cfg: &RunConfig, method: Method) -> OptimizerSpec {
    match cfg.spec {
        Some(s) if s.method == method => s,
        _ => spec_for(cfg.experiment, method),
    }
}

/// The paper's per-figure method lineup.
pub fn default_methods(id: ExperimentId) -> Vec<Method> {
    use Method::*;
    match id {
        ExperimentId::Fig4Pca | ExperimentId::Fig4Procrustes => {
            vec![Pogo, Landing, LandingPC, Slpg, Rgd, Rsdm]
        }
        ExperimentId::Fig5Ovit
        | ExperimentId::Fig1CnnFilters
        | ExperimentId::Fig1CnnKernels => {
            vec![Pogo, Landing, LandingPC, Slpg, Rgd, Rsdm, Adam]
        }
        // §5.3: RSDM removed (never came close); Adam infeasible by design.
        ExperimentId::Fig8Born => vec![Pogo, Landing, LandingPC, Slpg, Rgd],
        ExperimentId::FigC1Precision => vec![Pogo, Landing, Rsdm, Rgd],
        ExperimentId::FigC2Lambda => vec![Pogo],
        ExperimentId::ScaleMatrices => vec![Pogo, Landing, Rgd, Rsdm],
    }
}

/// Default step budgets (scaled; the paper's originals in comments).
pub fn default_steps(id: ExperimentId) -> usize {
    match id {
        // Paper: 3000 iterations with early stop at gap 1e-6.
        ExperimentId::Fig4Pca | ExperimentId::Fig4Procrustes => 600,
        // Paper: 10 epochs (ViT), 100 epochs (CNN).
        ExperimentId::Fig5Ovit => 60,
        ExperimentId::Fig1CnnFilters | ExperimentId::Fig1CnnKernels => 80,
        // Paper: 200 epochs with plateau halving + early stop.
        ExperimentId::Fig8Born => 300,
        ExperimentId::FigC1Precision => 200,
        ExperimentId::FigC2Lambda => 200,
        ExperimentId::ScaleMatrices => 20,
    }
}

/// Per-method hyperparameters for an experiment — the grid-search winners
/// reported in the paper's §C, adapted where our scaled shapes need it.
pub fn spec_for(id: ExperimentId, method: Method) -> OptimizerSpec {
    use ExperimentId as E;
    use Method::*;
    let spec = |lr: f64| OptimizerSpec::new(method, lr);
    match id {
        // §C.1 (PCA): lrs — RGD 0.15, RSDM 1.5 (r=700), Landing/POGO 0.25,
        // LandingPC 10.5 (λ 0.01), SLPG 0.125; POGO base momentum 0.3.
        E::Fig4Pca => match method {
            Rgd => spec(0.15),
            Rsdm => spec(1.5).with_submanifold(150), // paper 700/2000 → 150/400
            Landing => spec(0.25).with_base(BaseOptKind::momentum(0.1)),
            // Paper: lr 10.5, λ 0.01 at n=2000; at n=400 the normalized-
            // gradient step must stay ≲ O(1) against a √p ≈ 17 matrix norm,
            // and the weak attraction no longer recovers it — re-centred.
            LandingPC => spec(0.5).with_attraction(1.0),
            Slpg => spec(0.125),
            Pogo => spec(0.25).with_base(BaseOptKind::momentum(0.3)),
            Adam => spec(0.01),
        },
        // §C.1 (Procrustes): paper lrs (RGD 0.5, RSDM 2 at r=900, …) are
        // for normalized 2000² problems; our scaled 400² problem has
        // much larger raw gradients, so the grid re-centers lower.
        E::Fig4Procrustes => match method {
            Rgd => spec(1e-4),
            Rsdm => spec(4e-4).with_submanifold(180), // paper 900/2000 → 180/400
            Landing => spec(1e-4).with_base(BaseOptKind::momentum(0.1)),
            LandingPC => spec(0.5).with_attraction(1.0),
            Slpg => spec(1e-4),
            Pogo => spec(1e-4).with_base(BaseOptKind::momentum(0.1)),
            Adam => spec(0.01),
        },
        // §C.2 (O-ViT): RGD 0.1, RSDM 0.5 (r=300), Landing 1e-3 (mom 0.1),
        // LandingPC/SLPG/POGO 0.01 (POGO with SGD).
        E::Fig5Ovit => match method {
            Rgd => spec(0.1),
            Rsdm => spec(0.5).with_submanifold(48), // paper 300/1024 → 48/128
            Landing => spec(1e-3).with_base(BaseOptKind::momentum(0.1)),
            LandingPC => spec(0.01).with_attraction(1.0),
            Slpg => spec(0.01),
            Pogo => spec(0.01),
            Adam => spec(1e-3),
        },
        // §C.3 (CNN filters): RGD/Adam 0.01, RSDM 0.1 (r=64), SLPG/Landing
        // 1e-3 (Landing mom 0.6), LandingPC/POGO 0.5 (POGO + VAdam).
        E::Fig1CnnFilters => match method {
            Rgd => spec(0.01),
            Rsdm => spec(0.1).with_submanifold(24),
            Slpg => spec(1e-3),
            Landing => spec(1e-3).with_base(BaseOptKind::momentum(0.6)),
            LandingPC => spec(0.5).with_attraction(1.0),
            Pogo => spec(0.5).with_base(BaseOptKind::vadam()),
            Adam => spec(0.01),
        },
        // §C.3 (CNN kernels): RGD/Adam/Landing 0.01, RSDM 0.5 (r=2),
        // SLPG 0.1, LandingPC/POGO 0.5 (POGO + VAdam). The paper's POGO
        // lr 0.5 assumes thousands of steps; at our ~80-step budget a
        // per-matrix-normalized step of 0.5 spins each 3×3 (‖X‖=√3) too
        // fast to learn — the grid re-centres at 0.02.
        E::Fig1CnnKernels => match method {
            Rgd => spec(0.01),
            Rsdm => spec(0.5).with_submanifold(2),
            Landing => spec(0.01),
            Slpg => spec(0.1),
            LandingPC => spec(0.05).with_attraction(1.0),
            Pogo => spec(0.02).with_base(BaseOptKind::vadam()),
            Adam => spec(0.01),
        },
        // §C.4 (squared unitary PCs): RGD/LandingPC 0.05 (λ 0.1),
        // Landing 0.01, POGO 0.5 + VAdam, SLPG 5e-4.
        E::Fig8Born => match method {
            Rgd => spec(0.05),
            LandingPC => spec(0.05).with_attraction(0.1),
            Landing => spec(0.01),
            Pogo => spec(0.5).with_base(BaseOptKind::vadam()),
            Slpg => spec(5e-4),
            Rsdm => spec(0.05).with_submanifold(4),
            Adam => spec(1e-3),
        },
        // Ablations reuse the PCA lineup at its lrs.
        E::FigC1Precision => spec_for(E::Fig4Pca, method),
        E::FigC2Lambda => OptimizerSpec::new(Method::Pogo, 0.01),
        E::ScaleMatrices => match method {
            Pogo => spec(0.5).with_base(BaseOptKind::vadam()).with_engine(Engine::Xla),
            Landing => spec(0.01),
            Rgd => spec(0.01),
            Rsdm => spec(0.5).with_submanifold(2),
            _ => spec(0.01),
        },
    }
}

/// POGO's λ policy per experiment (default Half everywhere; the C.2
/// ablation sweeps both).
pub fn default_lambda() -> LambdaPolicy {
    LambdaPolicy::Half
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn experiment_ids_roundtrip() {
        for &id in ExperimentId::all() {
            assert_eq!(ExperimentId::parse(id.name()), Some(id));
        }
        assert!(ExperimentId::parse("nope").is_none());
    }

    #[test]
    fn specs_exist_for_all_method_experiment_pairs() {
        for &id in ExperimentId::all() {
            for &m in Method::all() {
                let s = spec_for(id, m);
                assert!(s.lr > 0.0, "{:?}/{}", id, m.name());
            }
        }
    }

    #[test]
    fn default_methods_match_paper_lineups() {
        assert_eq!(default_methods(ExperimentId::Fig4Pca).len(), 6);
        assert!(!default_methods(ExperimentId::Fig8Born).contains(&Method::Rsdm));
        assert!(default_methods(ExperimentId::Fig5Ovit).contains(&Method::Adam));
    }

    #[test]
    fn run_config_serializes() {
        let cfg = RunConfig::new(ExperimentId::Fig4Pca);
        let j = cfg.to_json();
        assert_eq!(j.get("experiment").as_str(), Some("fig4-pca"));
        assert!(j.get("methods").as_arr().unwrap().len() >= 5);
        assert_eq!(j.get("spec"), &Json::Null);
    }

    #[test]
    fn spec_override_wins_for_its_method_only() {
        let mut cfg = RunConfig::new(ExperimentId::Fig4Pca);
        let custom = OptimizerSpec::new(Method::Pogo, 123.0);
        cfg.spec = Some(custom);
        assert_eq!(resolve_spec(&cfg, Method::Pogo), custom);
        // Other methods keep their paper presets.
        assert_eq!(resolve_spec(&cfg, Method::Rgd), spec_for(ExperimentId::Fig4Pca, Method::Rgd));
        cfg.spec = None;
        assert_eq!(resolve_spec(&cfg, Method::Pogo), spec_for(ExperimentId::Fig4Pca, Method::Pogo));
    }
}
