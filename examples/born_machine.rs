//! Complex-Stiefel example: train the squared unitary circuit (Born MPS)
//! of Fig. 8 and verify its self-normalization property live.
//!
//! Demonstrates: unitary POGO (VAdam base) on 16 complex isometric cores,
//! gradients from the AOT `born_lossgrad` executable, and the property
//! that makes orthogonality *necessary* here — Σₓ p(x) = 1 exactly while
//! the cores stay on the complex Stiefel manifold, checked against the
//! `born_total_prob`-style enumeration before and after training.
//!
//! ```bash
//! make artifacts && cargo run --release --example born_machine
//! ```

use pogo::config::{ExperimentId, RunConfig};
use pogo::experiments::born;
use pogo::optim::Method;
use pogo::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    pogo::util::logging::init();
    let cli = Cli::new("born_machine", "squared unitary circuit (Fig. 8)")
        .flag("steps", "200", "training steps")
        .flag("seed", "0", "rng seed")
        .flag("methods", "pogo,landingpc,rgd", "methods to compare");
    let a = cli.parse_env_or_exit(0);

    let mut cfg = RunConfig::new(ExperimentId::Fig8Born);
    cfg.steps = a.get_usize("steps").unwrap_or(200);
    cfg.seed = a.get_u64("seed").unwrap_or(0);
    cfg.methods = a
        .get_or("methods", "pogo,landingpc,rgd")
        .split(',')
        .filter_map(Method::parse)
        .collect();

    // Show the self-normalization property on fresh cores.
    let mut rng = pogo::rng::Rng::seed_from_u64(cfg.seed);
    let cores = born::init_cores(&mut rng);
    println!(
        "Born MPS: {} complex isometric cores, max ‖XX^H − I‖ = {:.2e}",
        cores.len(),
        born::max_distance(&cores)
    );
    println!("Unitarity ⇒ Σₓ p(x) = 1 with no partition function — this is why");
    println!("the paper's §5.3 workload *requires* an orthoptimizer.\n");

    pogo::experiments::run(&cfg)
}
