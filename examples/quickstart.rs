//! Quickstart: optimize one orthogonal matrix with POGO's public API.
//!
//! Solves a small orthogonal Procrustes problem (`min ‖AX − B‖²` over
//! St(p, n)) three ways — POGO(λ=1/2), POGO(find-root), and RGD-QR — and
//! prints the loss/feasibility trajectory of each. Every optimizer comes
//! from one serializable [`OptimizerSpec`] through the crate's single
//! construction path, `build::<S>`.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pogo::coordinator::OptimizerSpec;
use pogo::linalg::{matmul, matmul_at_b, MatF};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::pogo::LambdaPolicy;
use pogo::optim::Method;
use pogo::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::seed_from_u64(42);
    let (p, n) = (32, 64);

    // Problem: align A·X with B over row-orthonormal X.
    let a = MatF::randn(p, p, &mut rng);
    let b = MatF::randn(p, n, &mut rng);
    let lossgrad = |x: &MatF| {
        let r = matmul(&a, x).sub(&b);
        (r.norm_sq() as f64, matmul_at_b(&a, &r).scale(2.0))
    };

    let x0 = stiefel::random_point(p, n, &mut rng);
    println!("St({p}, {n}) Procrustes; initial loss {:.2}\n", lossgrad(&x0).0);
    println!("{:<18} {:>10} {:>14} {:>12}", "optimizer", "steps", "final loss", "‖XXᵀ−I‖");

    // Three specs, one construction path, one trait.
    let specs = [
        OptimizerSpec::new(Method::Pogo, 0.05).with_base(BaseOptKind::vadam()),
        OptimizerSpec::new(Method::Pogo, 0.05)
            .with_base(BaseOptKind::vadam())
            .with_lambda(LambdaPolicy::FindRoot),
        OptimizerSpec::new(Method::Rgd, 2e-4),
    ];

    for spec in specs {
        let mut opt = spec.build::<f32>(None, (1, p, n))?;
        let mut x = x0.clone();
        let steps = 300;
        for _ in 0..steps {
            let (_, g) = lossgrad(&x);
            opt.step(0, &mut x, &g)?;
        }
        let (loss, _) = lossgrad(&x);
        println!(
            "{:<18} {:>10} {:>14.2} {:>12.2e}",
            opt.name(),
            steps,
            loss,
            stiefel::distance(&x)
        );
    }

    println!("\nPOGO stays on the manifold at every step with only matrix products —");
    println!("no QR/SVD — which is what lets it batch to thousands of matrices.");
    println!("Next: `cargo run --release --example cnn_kernels` for the batched regime.");
    Ok(())
}
