//! END-TO-END VALIDATION DRIVER (DESIGN.md §6): train a causal transformer
//! LM whose 16 attention projections are orthogonally constrained and
//! updated by POGO, on a real (synthetic-corpus) next-token workload.
//!
//! Every layer of the stack is on the path:
//!   L1  batched POGO Pallas kernel            (inside the step program)
//!   L2  transformer fwd/bwd JAX graph          (lm_lossgrad artifact)
//!   L3  this coordinator: data, routing, Adam for free params, telemetry
//!
//! The loss curve (nats/token) must fall from ~ln 64 ≈ 4.16 toward the
//! corpus' conditional-entropy floor (~1.0) while every attention matrix
//! stays on St(256, 256). Results are logged to results/e2e_lm_*.csv and
//! recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_transformer -- --steps 300
//! ```

use pogo::coordinator::{OptimizerSpec, ParamStore, Trainer, TrainerConfig};
use pogo::data::corpus::Corpus;
use pogo::linalg::MatF;
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::{Engine, Method};
use pogo::rng::Rng;
use pogo::runtime::{Arg, Registry};
use pogo::util::cli::Cli;

// Mirrors python/compile/models/transformer.py.
const N_ORTH: usize = 16;
const DIM: usize = 256;
const LAYERS: usize = 4;
const VOCAB: usize = 64;
const SEQ: usize = 128;
const BATCH: usize = 8;
const MLP_HIDDEN: usize = 4 * DIM;

fn main() -> anyhow::Result<()> {
    pogo::util::logging::init();
    let cli = Cli::new("e2e_transformer", "end-to-end LM training driver")
        .flag("steps", "300", "training steps")
        .flag("seed", "0", "rng seed")
        .flag("lr", "0.5", "POGO learning rate (VAdam base)")
        .flag("eval-every", "20", "validation cadence");
    let a = cli.parse_env_or_exit(0);
    let steps = a.get_usize("steps").unwrap_or(300);
    let seed = a.get_u64("seed").unwrap_or(0);
    let lr = a.get_f64("lr").unwrap_or(0.5);
    let eval_every = a.get_usize("eval-every").unwrap_or(20);

    let reg = Registry::open_default()?;
    let lossgrad = reg.get("lm_lossgrad")?;
    let evaler = reg.get("lm_eval")?;
    let mut rng = Rng::seed_from_u64(seed);
    let mut corpus = Corpus::new(seed);
    let eval_tokens = corpus.batch(BATCH, SEQ + 1);

    // ---- Parameter store: 16 orthogonal (256,256) + free the rest. -----
    let mut store = ParamStore::new();
    for i in 0..N_ORTH {
        store.add_stiefel_keyed(
            format!("attn_{i}"),
            stiefel::random_point(DIM, DIM, &mut rng),
            "attn",
        );
    }
    let tok_idx = store.add_free("tok_emb", MatF::randn(VOCAB, DIM, &mut rng).scale(0.02));
    let pos_idx = store.add_free("pos_emb", MatF::randn(SEQ, DIM, &mut rng).scale(0.02));
    let mut w1_idx = Vec::new();
    let mut w2_idx = Vec::new();
    for l in 0..LAYERS {
        w1_idx.push(store.add_free(
            format!("mlp_w1_{l}"),
            MatF::randn(DIM, MLP_HIDDEN, &mut rng).scale(0.02),
        ));
        w2_idx.push(store.add_free(
            format!("mlp_w2_{l}"),
            MatF::randn(MLP_HIDDEN, DIM, &mut rng).scale(0.02),
        ));
    }
    let head_idx = store.add_free("head", MatF::randn(DIM, VOCAB, &mut rng).scale(0.02));
    let n_params = store.len();
    println!(
        "transformer: {} params ({} scalars), {} orthogonal attention matrices",
        n_params,
        store.num_scalars(),
        N_ORTH
    );

    // POGO(VAdam) on the orthogonal group via the AOT (Pallas) step;
    // Adam on everything else.
    let spec = OptimizerSpec::new(Method::Pogo, lr)
        .with_base(BaseOptKind::vadam())
        .with_engine(Engine::Xla);
    let mut tr = Trainer::new(
        store,
        spec,
        Some(&reg),
        TrainerConfig { max_steps: steps, log_every: eval_every, free_lr: 1e-3,
                        ..Default::default() },
    )?;

    // ---- Gradient source: one lm_lossgrad dispatch per step. -----------
    let pack_args = |store: &ParamStore, tokens: &[i32]| -> anyhow::Result<Vec<MatF>> {
        let _ = tokens;
        let orth: Vec<MatF> = (0..N_ORTH).map(|i| store.mat(i).clone()).collect();
        Ok(orth)
    };
    let _ = pack_args; // (packing happens inline below)

    let run_lossgrad = |store: &ParamStore,
                        tokens: &[i32]|
     -> anyhow::Result<(f64, Vec<MatF>)> {
        let orth: Vec<MatF> = (0..N_ORTH).map(|i| store.mat(i).clone()).collect();
        let orth_packed = pogo::runtime::pack_batch(&orth)?;
        let w1: Vec<MatF> = w1_idx.iter().map(|&i| store.mat(i).clone()).collect();
        let w2: Vec<MatF> = w2_idx.iter().map(|&i| store.mat(i).clone()).collect();
        let w1_packed = pogo::runtime::pack_batch(&w1)?;
        let w2_packed = pogo::runtime::pack_batch(&w2)?;
        let outs = lossgrad.run(&[
            Arg::F32(&orth_packed, vec![N_ORTH, DIM, DIM]),
            Arg::Mat(store.mat(tok_idx)),
            Arg::Mat(store.mat(pos_idx)),
            Arg::F32(&w1_packed, vec![LAYERS, DIM, MLP_HIDDEN]),
            Arg::F32(&w2_packed, vec![LAYERS, MLP_HIDDEN, DIM]),
            Arg::Mat(store.mat(head_idx)),
            Arg::I32(tokens, vec![BATCH, SEQ + 1]),
        ])?;
        let loss = pogo::runtime::literal_to_scalar(&outs[0])? as f64;
        // Unpack gradients back into store order.
        let mut grads = vec![MatF::zeros(1, 1); n_params];
        let g_orth = pogo::runtime::literal_to_vec(&outs[1])?;
        for i in 0..N_ORTH {
            let per = DIM * DIM;
            grads[i] = MatF::from_vec(DIM, DIM, g_orth[i * per..(i + 1) * per].to_vec());
        }
        grads[tok_idx] = pogo::runtime::literal_to_mat(&outs[2], VOCAB, DIM)?;
        grads[pos_idx] = pogo::runtime::literal_to_mat(&outs[3], SEQ, DIM)?;
        let g_w1 = pogo::runtime::literal_to_vec(&outs[4])?;
        let g_w2 = pogo::runtime::literal_to_vec(&outs[5])?;
        for l in 0..LAYERS {
            let per1 = DIM * MLP_HIDDEN;
            grads[w1_idx[l]] =
                MatF::from_vec(DIM, MLP_HIDDEN, g_w1[l * per1..(l + 1) * per1].to_vec());
            grads[w2_idx[l]] =
                MatF::from_vec(MLP_HIDDEN, DIM, g_w2[l * per1..(l + 1) * per1].to_vec());
        }
        grads[head_idx] = pogo::runtime::literal_to_mat(&outs[6], DIM, VOCAB)?;
        Ok((loss, grads))
    };

    let eval_loss = |store: &ParamStore| -> anyhow::Result<f64> {
        let orth: Vec<MatF> = (0..N_ORTH).map(|i| store.mat(i).clone()).collect();
        let orth_packed = pogo::runtime::pack_batch(&orth)?;
        let w1: Vec<MatF> = w1_idx.iter().map(|&i| store.mat(i).clone()).collect();
        let w2: Vec<MatF> = w2_idx.iter().map(|&i| store.mat(i).clone()).collect();
        let w1_packed = pogo::runtime::pack_batch(&w1)?;
        let w2_packed = pogo::runtime::pack_batch(&w2)?;
        let outs = evaler.run(&[
            Arg::F32(&orth_packed, vec![N_ORTH, DIM, DIM]),
            Arg::Mat(store.mat(tok_idx)),
            Arg::Mat(store.mat(pos_idx)),
            Arg::F32(&w1_packed, vec![LAYERS, DIM, MLP_HIDDEN]),
            Arg::F32(&w2_packed, vec![LAYERS, MLP_HIDDEN, DIM]),
            Arg::Mat(store.mat(head_idx)),
            Arg::I32(&eval_tokens, vec![BATCH, SEQ + 1]),
        ])?;
        Ok(pogo::runtime::literal_to_scalar(&outs[0])? as f64)
    };

    // ---- Training loop. -------------------------------------------------
    println!(
        "{:>6} {:>10} {:>10} {:>12} {:>10}",
        "step", "train", "val", "‖XXᵀ−I‖max", "t(s)"
    );
    let floor = Corpus::new(seed).entropy_floor_nats();
    let sw = pogo::util::Stopwatch::start();
    for s in 0..steps {
        let tokens = corpus.batch(BATCH, SEQ + 1);
        let loss = {
            let mut src =
                |store: &ParamStore| run_lossgrad(store, &tokens);
            tr.step(&mut src)?
        };
        if s % eval_every == 0 || s + 1 == steps {
            let val = eval_loss(&tr.store)?;
            let d = tr.store.max_stiefel_distance();
            tr.log.record(tr.step_idx(), &[
                ("loss", loss),
                ("val_loss", val),
                ("distance", d),
            ]);
            println!(
                "{:>6} {:>10.4} {:>10.4} {:>12.2e} {:>10.1}",
                s,
                loss,
                val,
                d,
                sw.seconds()
            );
        }
    }

    let csv = pogo::repo_root().join("results/e2e_lm_pogo.csv");
    tr.log.write_csv(&csv)?;
    let final_val = tr.log.last("val_loss").unwrap_or(f64::NAN);
    let d = tr.store.max_stiefel_distance();
    println!("\nfinal val loss {final_val:.4} nats/token (uniform ln64 = {:.3}, corpus",
             (VOCAB as f64).ln());
    println!("conditional-entropy floor ≈ {floor:.3}); max manifold distance {d:.2e}");
    println!("series → {}", csv.display());
    if steps >= 200 {
        // Success = clearly below the uniform prior ln(V) ≈ 4.159 with a
        // monotone trend (reaching the ~1.0 floor takes tens of thousands
        // of CPU steps; the composition proof only needs real learning).
        let uniform = (VOCAB as f64).ln();
        anyhow::ensure!(
            final_val < uniform - 0.25,
            "LM failed to learn (val {final_val} vs uniform {uniform:.3})"
        );
    }
    anyhow::ensure!(d < 1e-2, "attention matrices left the manifold ({d})");
    println!("E2E OK: all three layers composed on a real training workload.");
    Ok(())
}
