//! The many-matrix regime: train a CNN whose 9 800 3×3 kernels are all
//! orthogonally constrained (Fig. 1/7's workload), end to end:
//!
//! L2/L1: the CNN forward/backward and the batched POGO(VAdam) step are
//! AOT JAX/Pallas executables; L3 (this program): synthetic-CIFAR batches,
//! shape-grouped dispatch, Adam on the classifier head, accuracy telemetry.
//!
//! ```bash
//! make artifacts && cargo run --release --example cnn_kernels -- --steps 40
//! ```

use pogo::config::{ExperimentId, RunConfig};
use pogo::experiments::cnn;
use pogo::optim::Method;
use pogo::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    pogo::util::logging::init();
    let cli = Cli::new("cnn_kernels", "orthogonal-kernel CNN (Fig. 1/7)")
        .flag("steps", "40", "training steps")
        .flag("seed", "0", "rng seed")
        .flag("methods", "pogo,adam", "methods to compare");
    let a = cli.parse_env_or_exit(0);

    let mut cfg = RunConfig::new(ExperimentId::Fig1CnnKernels);
    cfg.steps = a.get_usize("steps").unwrap_or(40);
    cfg.seed = a.get_u64("seed").unwrap_or(0);
    cfg.methods = a
        .get_or("methods", "pogo,adam")
        .split(',')
        .filter_map(Method::parse)
        .collect();

    println!(
        "Training the Fig. 1 CNN with {} orthogonal 3x3 kernels…",
        cnn::KERNEL_COUNTS.iter().sum::<usize>()
    );
    pogo::experiments::run(&cfg)
}
